"""Gopher Mesh: capacity-tiered physical exchange.

Contract under test:
  - tier classification is deterministic: structural occupancy excludes
    silent pairs, the EWMA profile demotes quiet pairs to cold/warm, the
    structural prior can never overflow;
  - the schedule covers every non-excluded pair exactly once, with send and
    receive tables aligned, at any device count — and its round_slots is
    the static routed geometry;
  - the fused pack kernel (plan + tier truncation + value pack + spill
    flags) matches the PR 3 plan oracle, on both backends;
  - the tiered exchange is BIT-IDENTICAL to the dense mailbox for
    idempotent ⊕ (CC / SSSP, single and query-batched, both backends) while
    routing strictly less geometry; PageRank (⊕ = float sum) matches to
    allclose — XLA may reassociate sums differently between the two fused
    BSP loops, the same caveat test_wire applies to patched blocks;
  - a pair overflowing its tier width triggers the dense fallback retry
    (results still exact) and escalates the pair for the next run;
  - exchange='auto' resolves to dense on 'local' and tiered on 'shard_map';
  - the traffic profile lives on the host block, folds in observations via
    update_profile, and apply_delta pre-announces the dirty frontier.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (GopherEngine, PageRankProgram, SemiringProgram,
                        TierPlan, compat, device_block, host_graph_block,
                        init_max_vertex, make_sssp_init, update_profile)
from repro.core.tiers import (COLD, EXCLUDED, HOT, WARM,
                              occupancy_from_graph, occupancy_from_ob_inv)
from repro.gofs import EdgeDelta, apply_delta, bfs_grow_partition, road_grid
from repro.gofs.formats import PAD, partition_graph
from repro.kernels import ops


@pytest.fixture(scope="module")
def road():
    g = road_grid(22, 22, drop_frac=0.08, seed=3, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    return g, pg


def _mesh1():
    return compat.make_mesh((1,), ("parts",))


# ---------------- classification ----------------

def test_tier_classification_deterministic():
    occ = np.array([[0, 3, 40, 1],
                    [3, 0, 0, 12],
                    [40, 0, 0, 2],
                    [1, 12, 2, 0]], np.int64)
    ewma = np.array([[0.0, 0.2, 40.0, 1.0],
                     [3.0, 0.0, 5.0, 0.0],
                     [6.0, 9.0, 0.0, 2.0],
                     [0.4, 30.0, 2.0, 0.0]])
    plan = TierPlan.build(ewma, occ, cap=40, warm_div=8)
    assert plan.warm_cap == 5
    t = plan.tiers
    assert t[0, 0] == EXCLUDED                   # occupancy 0
    assert t[1, 2] == EXCLUDED                   # ewma > 0 but occupancy 0
    assert t[0, 1] == COLD                       # quiet (0.2 <= 0.5)
    assert t[3, 0] == COLD
    assert t[0, 2] == HOT                        # 40 > warm_cap
    assert t[2, 0] == HOT                        # min(6, 40) = 6 > 5
    assert t[1, 0] == WARM                       # 3 <= 5
    assert t[3, 1] == HOT                        # min(30, 12) = 12 > 5
    assert t[2, 3] == WARM                       # min(2, 2) in (0.5, 5]
    lim = plan.limits()
    assert lim[0, 0] == 0 and lim[0, 1] == 1
    assert lim[1, 0] == 5 and lim[0, 2] == 40


def test_structural_plan_never_overflows(road):
    """expected == occupancy -> every pair's width covers its maximum
    possible count (the safe default the engine builds with no profile)."""
    g, pg = road
    plan = TierPlan.from_graph(pg)
    occ = occupancy_from_graph(pg)
    lim = plan.limits()
    assert np.all(lim >= occ)
    assert np.all((occ == 0) == (plan.tiers == EXCLUDED))


def test_plan_hashable_and_escalation():
    occ = np.array([[0, 2], [5, 0]], np.int64)
    plan = TierPlan.build(np.zeros((2, 2)), occ, cap=16)
    assert {plan: 1}[TierPlan.build(np.zeros((2, 2)), occ, cap=16)] == 1
    assert plan.tiers[0, 1] == COLD and plan.tiers[1, 0] == COLD
    up = plan.escalate(np.array([[False, True], [False, False]]))
    assert up.tiers[0, 1] == WARM and up.tiers[1, 0] == COLD
    assert up.escalations_from(plan) == 1
    up2 = up.escalate(np.ones((2, 2), bool))
    assert up2.tiers[0, 1] == HOT and up2.tiers[1, 0] == WARM
    # an EXCLUDED pair that somehow overflowed jumps straight to HOT
    assert up2.tiers[0, 0] == HOT
    assert up2.escalate(np.ones((2, 2), bool)).tiers[0, 1] == HOT  # clamps


# ---------------- schedule ----------------

@pytest.mark.parametrize("D", [1, 2, 4])
def test_schedule_covers_every_pair_once(D):
    rng = np.random.default_rng(D)
    P, cap = 8, 24
    occ = rng.integers(0, 10, (P, P))
    np.fill_diagonal(occ, 0)
    ewma = occ * rng.random((P, P))
    plan = TierPlan.build(ewma, occ, cap=cap)
    sched = plan.schedule(D)
    v = P // D
    seen = set()
    # hot: block (i, j) of the all_to_all
    for i in range(sched.D):
        for j in range(sched.D):
            for r in range(sched.hot_send.shape[2]):
                e = sched.hot_send[i, j, r]
                if e == PAD:
                    assert sched.hot_recv[j, i, r] == PAD
                    continue
                s = i * v + e // P
                d = e % P
                assert d // v == j
                assert sched.hot_recv[j, i, r] == (d % v) * P + s
                assert (s, d) not in seen
                seen.add((s, d))
    # hot residual + warm/cold: ppermute shifts
    for shifts in (sched.hot_res_shifts, sched.warm_shifts,
                   sched.cold_shifts):
        for k, gsz, send, recv in shifts:
            assert send.shape == (D, gsz) and recv.shape == (D, gsz)
            for i in range(D):
                j = (i + k) % D
                for r in range(gsz):
                    e = send[i, r]
                    if e == PAD:
                        assert recv[j, r] == PAD
                        continue
                    s = i * v + e // P
                    d = e % P
                    assert d // v == j
                    assert recv[j, r] == (d % v) * P + s
                    assert (s, d) not in seen
                    seen.add((s, d))
    want = {(s, d) for s, d in zip(*np.nonzero(plan.tiers != EXCLUDED))}
    assert seen == want


def test_round_slots_accounting():
    P, cap = 4, 16
    occ = np.array([[0, 9, 1, 0],
                    [9, 0, 0, 1],
                    [1, 0, 0, 0],
                    [0, 1, 0, 0]], np.int64)
    plan = TierPlan.build(occ, occ, cap=cap)     # structural: 2 hot, 4 cold
    assert plan.counts() == {"excluded": 10, "cold": 4, "warm": 0, "hot": 2}
    s1 = plan.schedule(1)
    # D=1: no padding — exactly 2 hot rows at cap + 4 cold rows at width 1
    assert s1.round_slots() == 2 * cap + 4
    assert s1.round_index_slots() == 4
    assert s1.device_round_slots() == s1.round_slots()
    # geometry is always <= the dense exchange's
    assert s1.round_slots() <= P * P * cap
    s2 = plan.schedule(2)
    assert s2.round_slots() >= s1.round_slots()  # residual padding only
    assert s2.device_round_slots() * 2 == s2.round_slots()
    # two-level hot: both hot pairs here live on device pair (0, 0), so the
    # uniform all_to_all block is empty and they ride the residual shift —
    # strictly below the old single-level layout that padded EVERY device
    # pair's block to the max count (2*2*2*cap slots of mostly padding)
    assert s2.hot_h == 0 and len(s2.hot_res_shifts) == 1
    assert s2.round_slots() < 2 * 2 * 2 * cap


# ---------------- fused pack kernel ----------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_pack_matches_plan_oracle(backend):
    rng = np.random.default_rng(11)
    R, cap = 6, 40
    act = rng.random((R, cap)) < 0.35
    vals = rng.uniform(-5, 5, (R, cap)).astype(np.float32)
    vals[0, np.flatnonzero(act[0])[:1]] = np.inf    # ±inf are legal messages
    full = jnp.full((R,), cap, jnp.int32)
    pvals, sids, pinv, counts, over = ops.outbox_pack(
        jnp.asarray(vals), jnp.asarray(act), full, np.inf, backend=backend,
        block_r=4)
    pfwd_o, pinv_o, counts_o = ops.outbox_compact_plan(jnp.asarray(act),
                                                       backend="jnp")
    assert np.array_equal(np.asarray(pinv), np.asarray(pinv_o))
    assert np.array_equal(np.asarray(counts), np.asarray(counts_o))
    assert np.array_equal(np.asarray(sids), np.asarray(pfwd_o))
    assert not np.asarray(over).any()
    # packed values = gather through the oracle's forward permutation
    has = np.asarray(pfwd_o) != PAD
    want = np.where(has, vals[np.arange(R)[:, None],
                              np.where(has, np.asarray(pfwd_o), 0)], np.inf)
    assert np.array_equal(np.asarray(pvals), want)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_pack_truncation_and_overflow(backend):
    rng = np.random.default_rng(12)
    R, cap = 5, 24
    act = rng.random((R, cap)) < 0.5
    vals = rng.uniform(0, 9, (R, cap)).astype(np.float32)
    lim = jnp.asarray(rng.integers(0, 6, R), jnp.int32)
    pvals, sids, pinv, counts, over = ops.outbox_pack(
        jnp.asarray(vals), jnp.asarray(act), lim, 0.0, backend=backend,
        block_r=4)
    counts, over = np.asarray(counts), np.asarray(over)
    assert np.array_equal(counts, act.sum(1))    # counts are PRE-truncation
    assert np.array_equal(over, (act.sum(1) > np.asarray(lim)).astype(np.int32))
    for r in range(R):
        k = min(int(counts[r]), int(lim[r]))
        keep = np.flatnonzero(act[r])[:k]
        assert np.array_equal(np.asarray(sids)[r, :k], keep)
        assert np.all(np.asarray(sids)[r, k:] == PAD)
        assert np.array_equal(np.asarray(pvals)[r, :k], vals[r, keep])
        assert np.all(np.asarray(pvals)[r, k:] == 0.0)
        # pinv maps only the kept slots
        assert np.array_equal(np.flatnonzero(np.asarray(pinv)[r] != PAD), keep)


def test_fused_pack_batched_matches_single():
    rng = np.random.default_rng(13)
    R, cap, Q = 4, 16, 3
    act = rng.random((R, cap)) < 0.4
    vals = rng.uniform(0, 9, (R, cap, Q)).astype(np.float32)
    lim = jnp.asarray(rng.integers(1, 5, R), jnp.int32)
    for backend in ("jnp", "pallas"):
        pv, sids, pinv, counts, over = ops.outbox_pack(
            jnp.asarray(vals), jnp.asarray(act), lim, 0.0, backend=backend)
        for q in range(Q):
            pq, sq, iq, cq, oq = ops.outbox_pack(
                jnp.asarray(vals[:, :, q]), jnp.asarray(act), lim, 0.0,
                backend="jnp")
            assert np.array_equal(np.asarray(pv)[:, :, q], np.asarray(pq))
            assert np.array_equal(np.asarray(sids), np.asarray(sq))
            assert np.array_equal(np.asarray(counts), np.asarray(cq))
            assert np.array_equal(np.asarray(over), np.asarray(oq))


# ---------------- engine: tiered == dense, both backends ----------------

def _programs(pg, n):
    return [
        ("cc", SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
         "x", True),
        ("sssp", SemiringProgram(
            semiring="min_plus",
            init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0]))),
         "x", True),
        # ⊕ = float sum: the two fused BSP loops may reassociate — allclose
        ("pagerank", PageRankProgram(n_global=n, num_iters=12), "r", False),
    ]


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_tiered_exchange_matches_dense(backend, road):
    g, pg = road
    mesh = _mesh1() if backend == "shard_map" else None
    for name, prog, key, exact in _programs(pg, g.n):
        sd, td = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                              exchange="dense").run()
        st, tt = GopherEngine(pg, prog, backend=backend, mesh=mesh,
                              exchange="tiered").run()
        a, b = np.asarray(sd[key]), np.asarray(st[key])
        if exact:
            assert np.array_equal(a, b), name
        else:
            assert np.allclose(a, b, rtol=1e-6, atol=1e-9), name
        assert td.supersteps == tt.supersteps
        assert tt.exchange == "tiered" and not tt.retried
        assert tt.spills == 0
        # physical geometry: static per round, strictly under dense
        P, cap = pg.num_parts, pg.mailbox_cap
        assert np.all(np.asarray(tt.wire_hist)
                      == np.asarray(tt.wire_hist)[0])
        assert tt.wire_slots < td.wire_slots
        assert tt.bytes_on_wire < td.bytes_on_wire
        assert tt.pair_slots is not None and tt.pair_slots.shape == (P, P)
        assert tt.pair_overflow is not None and tt.pair_overflow.sum() == 0


def test_tiered_query_batched_matches_dense(road):
    from repro.serving.batched import (BatchedSemiringProgram,
                                       gather_query_results, sssp_query_init)
    g, pg = road
    sources = [0, 5, g.n // 2, g.n - 1]
    prog = BatchedSemiringProgram(semiring="min_plus",
                                  num_queries=len(sources))
    extra = {"qinit": sssp_query_init(pg, sources)}
    sd, td = GopherEngine(pg, prog, exchange="dense").run_queries(extra=extra)
    st, tt = GopherEngine(pg, prog,
                          exchange="tiered").run_queries(extra=extra)
    assert np.array_equal(gather_query_results(pg, sd["x"]),
                          gather_query_results(pg, st["x"]))
    assert np.array_equal(td.query_supersteps, tt.query_supersteps)
    assert tt.spills == 0 and not tt.retried
    assert tt.wire_slots < td.wire_slots


def test_auto_resolves_per_backend(road):
    g, pg = road
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    local = GopherEngine(pg, prog)
    assert local.exchange_requested == "auto"
    # Gopher Hot: on the local backend an eligible program rides the fused
    # megastep route — one launch per superstep, nothing on the wire
    assert local.exchange == "megastep" and local.tier_plan is None
    # an ineligible program (bounded local fixpoint) stays dense
    capped = GopherEngine(pg, SemiringProgram(semiring="max_first",
                                              init_fn=init_max_vertex,
                                              max_local_iters=1))
    assert capped.exchange == "dense"
    # a DEGENERATE 1-device shard_map mesh is local in every physical sense
    # but the megastep route is vmap-only, so auto picks dense there
    sm = GopherEngine(pg, prog, backend="shard_map", mesh=_mesh1())
    assert sm.exchange == "dense" and sm.tier_plan is None
    # auto results match an explicit dense run on both backends
    sd, _ = GopherEngine(pg, prog, exchange="dense").run()
    sa, ta = local.run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(sa["x"]))
    assert ta.exchange == "megastep" and ta.wire_slots == 0
    sm_state, tm = sm.run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(sm_state["x"]))
    assert tm.exchange == "dense"
    # an EXPLICIT tiered request on the 1-device mesh is still honored
    st, tt = GopherEngine(pg, prog, backend="shard_map", mesh=_mesh1(),
                          exchange="tiered").run()
    assert tt.exchange == "tiered"
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))


# ---------------- overflow: dense fallback retry + escalation ----------------

def test_overflow_escalates_and_falls_back(road):
    g, pg = road
    prog = SemiringProgram(semiring="min_plus",
                           init_fn=make_sssp_init(int(pg.part_of[0]),
                                                  int(pg.local_of[0])))
    sd, _ = GopherEngine(pg, prog, exchange="dense").run()
    # sabotage the plan: demote the BUSIEST pair to cold (width 1) — a cold
    # SSSP run fires every slot of the pair in the prime round
    plan = TierPlan.from_graph(pg)
    occ = occupancy_from_graph(pg)
    s, d = np.unravel_index(np.argmax(occ), occ.shape)
    assert occ[s, d] > 1
    t = plan.tiers.copy()
    t[s, d] = COLD
    import dataclasses
    bad = dataclasses.replace(plan, tier_bytes=t.tobytes())
    eng = GopherEngine(pg, prog, exchange="tiered", tier_plan=bad)
    st, tt = eng.run()
    # results still exact (dense fallback), spill recorded, pair promoted
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert tt.retried and tt.spills > 0
    assert tt.exchange == "tiered"
    assert tt.escalations >= 1
    # the profile observation covers the ABORTED tiered attempt's rounds
    assert tt.pair_rounds >= 1
    assert tt.pair_slots.sum() > 0
    assert tt.pair_overflow[s, d] > 0
    assert eng.tier_plan.tiers[s, d] > COLD
    # escalation converges: within the tier ladder the same engine stops
    # spilling and goes back to pure tiered runs
    for _ in range(3):
        st, tt = eng.run()
        if not tt.retried:
            break
    assert not tt.retried and tt.spills == 0
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))


def test_tiered_multi_device_collectives():
    """The real thing: D=4 CPU devices (forced via XLA_FLAGS in a
    subprocess — the flag only takes effect before jax initializes), so
    the hot tier's all_to_all and the warm/cold ppermute round-robin
    actually cross device boundaries. Asserts CC + SSSP bit-parity with
    the dense exchange and a spill-free structural plan."""
    import subprocess
    import sys
    import os
    prog = r"""
import numpy as np
from repro.core import (GopherEngine, SemiringProgram, compat,
                        init_max_vertex, make_sssp_init)
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
g = road_grid(14, 14, drop_frac=0.05, seed=1, weighted=True)
pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)   # v=2/device
mesh = compat.make_mesh((4,), ("parts",))
# auto picks the tiered wire on a REAL multi-device mesh (vs dense at D=1)
assert GopherEngine(pg, SemiringProgram(semiring="max_first",
                                        init_fn=init_max_vertex),
                    backend="shard_map", mesh=mesh).exchange == "tiered"
for prog in (SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
             SemiringProgram(semiring="min_plus",
                             init_fn=make_sssp_init(int(pg.part_of[0]),
                                                    int(pg.local_of[0])))):
    sd, td = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                          exchange="dense").run()
    st, tt = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                          exchange="tiered").run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))
    assert tt.spills == 0 and not tt.retried
    # structural plans on a dense-ish toy mesh can pad up to the dense
    # geometry (h -> v^2); the profile, not structure, buys the big wins
    assert tt.wire_slots <= td.wire_slots
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------- traffic profile ----------------

def test_profile_update_and_announce(road):
    g, pg = road
    hb = host_graph_block(pg)
    occ = occupancy_from_ob_inv(hb["ob_inv"])
    assert np.array_equal(hb["wire_ewma"], occ.astype(np.float32))
    # a quiet run decays the profile toward zero...
    update_profile(hb, np.zeros_like(occ), rounds=1, decay=0.25)
    assert np.allclose(hb["wire_ewma"], 0.25 * occ)
    update_profile(hb, np.zeros_like(occ), rounds=1, decay=0.25)
    plan = TierPlan.from_block(hb)
    # ...so busy-in-structure but quiet-in-history pairs leave the hot tier
    assert (plan.tiers == HOT).sum() < (TierPlan.from_graph(pg).tiers
                                        == HOT).sum()
    # an insert delta pre-announces its dirty frontier: the touched pair
    # rises to at least its expected prime-round count
    u = int(pg.global_id[0][np.flatnonzero(pg.vmask[0])[0]])
    other = int(pg.global_id[1][np.flatnonzero(pg.vmask[1])[0]])
    res = apply_delta(pg, EdgeDelta.inserts([u], [other], [1.0]),
                      directed=False, block=hb)
    ew = res.block["wire_ewma"]
    pu, pv = int(pg.part_of[u]), int(pg.part_of[other])
    assert ew[pu, pv] >= 1.0 and ew[pv, pu] >= 1.0
    # and an engine run with the rebuilt plan stays spill-free + exact
    plan2 = TierPlan.from_block(res.block)
    prog = SemiringProgram(semiring="min_plus",
                           init_fn=make_sssp_init(int(pg.part_of[0]),
                                                  int(pg.local_of[0])))
    gbd = device_block(res.block)
    sd, _ = GopherEngine(res.pg, prog, gb=gbd, exchange="dense").run()
    st, tt = GopherEngine(res.pg, prog, gb=gbd, exchange="tiered",
                          tier_plan=plan2).run()
    assert np.array_equal(np.asarray(sd["x"]), np.asarray(st["x"]))


def test_tiered_wire_tracks_quiet_profile(road):
    """The acceptance-shape check at test scale: converge, teach the
    profile, apply a small insert delta, and the tiered geometry for the
    incremental run lands well under the dense P²·cap per round."""
    from repro.algorithms import bfs
    g, pg = road
    hb = host_graph_block(pg)
    d_prev, _ = bfs(pg, 3)
    # teach: one converged compact run + one quiesced resume
    prog_cold = SemiringProgram(semiring="min_plus",
                                init_fn=make_sssp_init(int(pg.part_of[3]),
                                                       int(pg.local_of[3])))
    _, tele = GopherEngine(pg, prog_cold, gb=device_block(hb),
                           exchange="compact").run()
    update_profile(hb, tele.pair_slots, tele.supersteps + 1)
    x0 = np.where(pg.vmask, d_prev, np.inf).astype(np.float32)
    prog_res = SemiringProgram(semiring="min_plus", resume=True)
    _, tele_q = GopherEngine(pg, prog_res, gb=device_block(hb),
                             exchange="compact").run(
        extra={"x0": x0, "frontier0": np.zeros_like(pg.vmask)})
    update_profile(hb, tele_q.pair_slots, tele_q.supersteps + 1)
    # version k+1: small insert batch with heavy weights (no shortcuts), so
    # the incremental frontier stays small — the regime the tier profile
    # models; a shortcut-heavy delta would spill and take the dense retry,
    # which test_overflow_escalates_and_falls_back covers
    rng = np.random.default_rng(0)
    iu = rng.integers(0, g.n, 8)
    iv = rng.integers(0, g.n, 8)
    keep = iu != iv
    res = apply_delta(pg, EdgeDelta.inserts(
        iu[keep], iv[keep],
        rng.uniform(50.0, 60.0, int(keep.sum())).astype(np.float32)),
        directed=False, block=hb)
    pg1 = res.pg
    x1 = np.where(pg1.vmask, d_prev, np.inf).astype(np.float32)
    extra = {"x0": x1, "frontier0": res.dirty_insert & pg1.vmask}
    gbd = device_block(res.block)
    outs = {}
    for mode in ("dense", "tiered"):
        eng = GopherEngine(pg1, SemiringProgram(semiring="min_plus",
                                                resume=True),
                           gb=gbd, exchange=mode,
                           tier_plan=(TierPlan.from_block(res.block)
                                      if mode == "tiered" else None))
        state, tele = eng.run(extra=extra)
        outs[mode] = (np.asarray(state["x"]), tele)
    xd, td = outs["dense"]
    xt, tt = outs["tiered"]
    assert np.array_equal(xd, xt)
    assert tt.spills == 0 and not tt.retried
    P, cap = pg1.num_parts, pg1.mailbox_cap
    assert tt.wire_hist[0] <= 0.25 * P * P * cap
    assert tt.wire_slots <= 0.25 * td.wire_slots
