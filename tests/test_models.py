"""Per-architecture smoke tests (reduced configs) + numerical validation of
the mixers against naive recurrences + decode/forward parity."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

# LM-substrate end-to-end sweeps dominate suite wall time (~6 of 7 minutes);
# the fast CI lane deselects them, the tier-1 gate still runs everything
pytestmark = pytest.mark.slow

from repro.configs import ARCHS
from repro.models import (decode_step, forward, init_cache, init_params,
                          param_count, prefill)
from repro.models import layers as L


KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key=KEY):
    if cfg.embed_inputs:
        x = jax.random.normal(key, (B, S, cfg.d_model))
        pos = (jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
               if cfg.mrope else None)
        return x, pos
    return jax.random.randint(key, (B, S), 0, cfg.vocab), None


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_shapes(arch):
    """One fwd + one train grad step on the reduced config: shapes + no NaNs."""
    cfg = ARCHS[arch].reduced()
    B, S = 2, 16
    params = init_params(KEY, cfg, max_seq=S)
    x, pos = _inputs(cfg, B, S)
    logits, aux = forward(params, x, cfg, positions=pos)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one grad step
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    def loss_fn(p):
        lg, aux = forward(p, x, cfg, positions=pos)
        lg = lg.astype(jnp.float32)
        ls = -jnp.take_along_axis(jax.nn.log_softmax(lg), labels[..., None],
                                  axis=-1).mean()
        return ls + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-4b", "h2o-danube-1.8b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "deepseek-moe-16b", "qwen3-moe-30b-a3b",
                                  "qwen2-vl-2b", "qwen1.5-110b"])
def test_decode_matches_forward(arch):
    """Greedy per-token decode must reproduce teacher-forced logits."""
    cfg = ARCHS[arch].reduced()
    B, S = 2, 12
    params = init_params(KEY, cfg, max_seq=S)
    if cfg.embed_inputs:
        x, pos = _inputs(cfg, B, S)
        lg_full, _ = forward(params, x, cfg, positions=pos)
        cache = init_cache(cfg, B, S, jnp.float32)
        errs = []
        for t in range(S):
            p3 = jnp.broadcast_to(jnp.full((B, 1), t), (3, B, 1)) if cfg.mrope else None
            lg, cache = decode_step(params, x[:, t], cache, cfg, positions=p3)
            errs.append(float(jnp.abs(lg - lg_full[:, t]).max()))
    else:
        toks, _ = _inputs(cfg, B, S)
        lg_full, _ = forward(params, toks, cfg)
        cache = init_cache(cfg, B, S, jnp.float32)
        errs = []
        for t in range(S):
            lg, cache = decode_step(params, toks[:, t], cache, cfg)
            errs.append(float(jnp.abs(lg - lg_full[:, t]).max()))
    assert max(errs) < 1e-4, errs


@pytest.mark.parametrize("arch", ["llama3-8b", "whisper-small", "zamba2-1.2b",
                                  "falcon-mamba-7b", "gemma3-4b"])
def test_prefill_then_decode(arch):
    cfg = ARCHS[arch].reduced()
    B, S, S0 = 2, 12, 8
    params = init_params(KEY, cfg, max_seq=S)
    toks, _ = _inputs(cfg, B, S)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model))
    lg_full, _ = forward(params, toks, cfg, **kw)
    lp, cache, _ = prefill(params, toks[:, :S0], cfg, max_seq=S, **kw)
    assert float(jnp.abs(lp - lg_full[:, :S0]).max()) < 1e-4
    for t in range(S0, S):
        lg, cache = decode_step(params, toks[:, t], cache, cfg)
        assert float(jnp.abs(lg - lg_full[:, t]).max()) < 1e-4


def test_mamba1_matches_naive_recurrence():
    """Chunked S6 scan == step-by-step recurrence (the Mamba1 oracle)."""
    cfg = ARCHS["falcon-mamba-7b"].reduced()
    B, S, d = 2, 24, cfg.d_model
    p = L.mamba1_params(KEY, cfg)
    x = jax.random.normal(KEY, (B, S, d)) * 0.3
    y_chunk, _ = L.mamba1_mixer(x, p, cfg, chunk=8)
    # naive: feed one token at a time through the stateful path
    state = {"conv": jnp.zeros((B, cfg.ssm.d_conv - 1, cfg.ssm.expand * d)),
             "ssm": jnp.zeros((B, cfg.ssm.expand * d, cfg.ssm.d_state))}
    outs = []
    for t in range(S):
        y, state = L.mamba1_mixer(x[:, t:t + 1], p, cfg, state=state)
        outs.append(y)
    y_naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-4)


def test_mamba2_matches_naive_recurrence():
    """Chunked SSD == stepwise recurrence (the Mamba2 oracle)."""
    cfg = ARCHS["zamba2-1.2b"].reduced()
    B, S, d = 2, 24, cfg.d_model
    p = L.mamba2_params(KEY, cfg)
    x = jax.random.normal(KEY, (B, S, d)) * 0.3
    y_chunk, _ = L.mamba2_mixer(x, p, cfg, chunk=8)
    s = cfg.ssm
    state = {"conv": jnp.zeros((B, s.d_conv - 1, s.n_heads * s.head_dim + 2 * s.d_state)),
             "ssm": jnp.zeros((B, s.n_heads, s.head_dim, s.d_state))}
    outs = []
    for t in range(S):
        y, state = L.mamba2_mixer(x[:, t:t + 1], p, cfg, state=state)
        outs.append(y)
    y_naive = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-3, atol=2e-4)


def test_flash_attention_matches_naive():
    B, S, H, KV, dh = 2, 32, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, dh))
    got = L.flash_attention(q, k, v, causal=True, q_block=8, kv_block=8)
    # naive reference
    g = H // KV
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(dh)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flash_attention_window():
    B, S, H, dh, W = 1, 32, 2, 8, 8
    q = jax.random.normal(KEY, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(4), (B, S, H, dh))
    got = L.flash_attention(q, k, v, causal=True, window=W, q_block=8, kv_block=8)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = (kj <= qi) & (qi - kj < W)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_moe_aux_loss_and_balance():
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    p = L.moe_params(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = L.moe_block(x, p, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0  # switch aux loss active
    e = cfg.moe
    # perfectly balanced router would give aux = coef
    assert float(aux) < e.aux_loss_coef * e.n_experts


def test_param_count_analytic_close_to_actual():
    for arch in ["llama3-8b", "deepseek-moe-16b", "falcon-mamba-7b"]:
        cfg = ARCHS[arch].reduced()
        params = init_params(KEY, cfg, max_seq=16)
        actual = param_count(params)
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.2, (arch, actual, est)
