"""Gopher Scope: tracing, metrics and skew analytics.

Contract under test:
  - the metrics registry is Prometheus-shaped (labeled counters / gauges /
    bounded histograms), snapshots to a schema-valid dict, and hands back
    the same metric object per (name, labels);
  - the tracer nests spans run -> phase -> superstep -> stage, exports a
    valid Chrome trace, and DISABLED degenerates to the shared no-op span
    (no span objects, no recording);
  - Telemetry's round-indexed wire accounting holds across ALL SIX
    exchange disciplines: wire_hist has supersteps+1 entries summing to
    wire_slots, count_hist is consistent with pair_slots, phase
    annotations are monotone (the megastep route ships nothing — its wire
    accounting is all zero while the logical counts persist);
  - the traced stepped driver is bit-identical to the fused compiled loop
    (states AND telemetry), on every discipline — tracing observes, never
    perturbs;
  - the engine, tier planner and serving loop feed the registry, and
    GraphQueryService.stats() reports latency percentiles, cache hit rate
    and live per-partition imbalance.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import GopherEngine, PhasedTierPlan, SemiringProgram, TierPlan
from repro.core import init_max_vertex, make_sssp_init
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
from repro.obs import (MetricsRegistry, SkewTracker, Tracer, imbalance_score,
                       skew_report, validate_chrome_trace, validate_metrics)
from repro.obs.trace import _NOOP_SPAN

MODES = ("dense", "compact", "tiered", "phased", "megastep", "auto")


@pytest.fixture(scope="module")
def road():
    g = road_grid(14, 14, drop_frac=0.05, seed=1, weighted=True)
    return g, partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)


def _prog(pg, algo="cc"):
    if algo == "cc":
        return SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    return SemiringProgram(
        semiring="min_plus",
        init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0])))


def _plan(pg, exchange):
    if exchange == "tiered":
        return TierPlan.from_graph(pg)
    if exchange == "phased":
        return PhasedTierPlan.from_graph(pg)
    return None


# ---------------- metrics registry ----------------

def test_metrics_registry_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", labels={"route": "a"})
    c.inc()
    c.inc(2)
    assert reg.counter("reqs_total", labels={"route": "a"}) is c
    assert c.value == 3
    reg.gauge("depth").set(7)
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    validate_metrics(snap)
    assert snap["counters"]["reqs_total{route=a}"] == 3
    assert snap["gauges"]["depth"] == 7
    s = snap["histograms"]["lat"]
    assert s["count"] == 4 and s["sum"] == 10.0 and s["p50"] == 2.5
    reg.clear()
    assert reg.snapshot()["counters"] == {}


def test_metrics_validate_rejects_garbage():
    with pytest.raises(AssertionError):
        validate_metrics({"format": "something-else"})
    with pytest.raises(AssertionError):
        validate_metrics({"format": "gopher-metrics-v1",
                          "counters": {"x": "not-a-number"},
                          "gauges": {}, "histograms": {}})


# ---------------- tracer ----------------

def test_tracer_nesting_and_chrome_export(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("run", kind="test") as run:
        with tr.span("phase", phase=0):
            with tr.span("superstep", step=0):
                with tr.span("sweep"):
                    pass
        run.set(supersteps=1)
    assert tr.balanced
    depths = {s.name: s.depth for s in tr.spans}
    assert depths == {"run": 0, "phase": 1, "superstep": 2, "sweep": 3}
    trace = tr.chrome_trace()
    validate_chrome_trace(trace)
    run_ev = next(e for e in trace["traceEvents"] if e["name"] == "run")
    assert run_ev["args"]["supersteps"] == 1
    p = tr.write_chrome_trace(str(tmp_path / "t.json"))
    import json
    validate_chrome_trace(json.load(open(p)))
    lines = tr.jsonl().splitlines()
    assert len(lines) == len(tr.spans)


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    s = tr.span("run", big=1)
    assert s is _NOOP_SPAN           # shared object, zero allocation
    with s as inner:
        inner.set(x=2)
    assert tr.spans == [] and tr.balanced


def test_unbalanced_spans_detected():
    tr = Tracer(enabled=True)
    span = tr.span("run")
    span.__enter__()
    assert not tr.balanced and tr.open_spans() == ["run"]
    span.__exit__(None, None, None)
    assert tr.balanced


# ---------------- skew analytics ----------------

def test_imbalance_score():
    assert imbalance_score(None) == 0.0
    assert imbalance_score(np.zeros(4)) == 0.0
    assert imbalance_score(np.ones(4)) == 1.0
    assert imbalance_score(np.array([4.0, 0, 0, 0])) == 4.0


def test_skew_tracker_accumulates_and_resets():
    class T:
        def __init__(self, li, ps=None):
            self.local_iters = np.asarray(li)
            self.pair_slots = ps
    tr = SkewTracker()
    tr.observe(T([2.0, 1.0, 1.0, 0.0], np.ones((4, 4))))
    tr.observe(T([2.0, 1.0, 1.0, 0.0], np.ones((4, 4))))
    assert tr.runs == 2 and tr.imbalance() == 2.0
    assert float(tr.pair_slots.sum()) == 32.0
    rep = tr.report()
    assert rep["straggler"] == 0 and rep["runs"] == 2
    tr.observe(T([1.0, 1.0]))        # repartition: shape change resets
    assert tr.liters.size == 2 and tr.pair_slots is None


# ---------------- Telemetry invariants, all five disciplines ----------------

@pytest.mark.parametrize("exchange", MODES)
@pytest.mark.parametrize("algo", ("cc", "sssp"))
def test_telemetry_round_invariants(road, exchange, algo):
    g, pg = road
    eng = GopherEngine(pg, _prog(pg, algo), exchange=exchange,
                       tier_plan=_plan(pg, exchange))
    state, t = eng.run()
    assert t.wire_hist is not None
    assert len(t.wire_hist) == t.supersteps + 1
    assert int(np.sum(t.wire_hist)) == t.wire_slots
    if t.exchange == "megastep":     # auto resolves here on local
        # fused route: no routed buffers at all — zero wire, zero bytes,
        # but the logical frontier observation still feeds the profiles
        assert t.wire_slots == 0 and t.bytes_on_wire == 0
        assert int(np.sum(t.count_hist)) > 0
    else:
        assert t.wire_hist[0] > 0    # the prime round is accounted
    if t.exchange == "dense":
        assert t.count_hist is None  # dense measures no packed counts
    else:
        assert len(t.count_hist) == t.supersteps + 1
        # pair_slots is the (P, P) breakdown of the same packed counts
        assert int(np.sum(t.pair_slots)) == int(np.sum(t.count_hist))
        assert t.pair_rounds == t.supersteps + 1   # no retry on this graph
    if t.exchange == "phased":
        assert len(t.phase_hist) == t.supersteps + 1
        assert t.phase_hist[0] == 0                 # prime ships in phase 0
        assert np.all(np.diff(t.phase_hist) >= 0)   # phases only advance
        assert int(np.sum(t.phase_wire)) == t.wire_slots
        sw = np.asarray(t.phase_switch_steps)
        assert np.all(np.diff(sw) > 0)              # strictly monotone
        assert np.sum(t.phase_pair_slots) == np.sum(t.pair_slots)


# ---------------- traced == untraced ----------------

@pytest.mark.parametrize("exchange", MODES)
def test_traced_run_bit_identical(road, exchange):
    g, pg = road
    prog = _prog(pg, "sssp")
    plan = _plan(pg, exchange)
    s0, t0 = GopherEngine(pg, prog, exchange=exchange, tier_plan=plan).run()
    tracer = Tracer(enabled=True)
    s1, t1 = GopherEngine(pg, prog, exchange=exchange, tier_plan=plan,
                          tracer=tracer).run()
    np.testing.assert_array_equal(np.asarray(s0["x"]), np.asarray(s1["x"]))
    assert t0.supersteps == t1.supersteps
    assert t0.wire_slots == t1.wire_slots
    np.testing.assert_array_equal(t0.wire_hist, t1.wire_hist)
    np.testing.assert_array_equal(t0.local_iters, t1.local_iters)
    if t0.count_hist is not None:
        np.testing.assert_array_equal(t0.count_hist, t1.count_hist)
        np.testing.assert_array_equal(t0.pair_slots, t1.pair_slots)
    # span tree: balanced, valid chrome, one superstep span per superstep
    assert tracer.balanced
    trace = tracer.chrome_trace()
    validate_chrome_trace(trace)
    names = [s.name for s in tracer.spans]
    assert names.count("superstep") == t1.supersteps
    if t1.exchange == "megastep":
        # one fused dispatch per superstep: a single 'megastep' child
        # replaces the staged sweep/pack/exchange trio
        assert names.count("megastep") == t1.supersteps
        assert "sweep" not in names and "exchange" not in names
        assert {"run", "phase", "prime", "halt-vote"} <= set(names)
    else:
        assert names.count("sweep") == t1.supersteps
        assert {"run", "phase", "prime", "pack", "exchange",
                "halt-vote"} <= set(names)


def test_traced_shard_map_phased():
    """The acceptance scenario: a phased shard_map traced run emits a valid
    Chrome trace with nested run -> phase -> superstep -> stage spans and
    matches the fused loop bit-for-bit."""
    prog = r"""
import numpy as np
from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                        compat, make_sssp_init)
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
from repro.obs import Tracer, validate_chrome_trace
g = road_grid(14, 14, drop_frac=0.05, seed=1, weighted=True)
pg = partition_graph(g, bfs_grow_partition(g, 8, seed=0), 8)
mesh = compat.make_mesh((4,), ("parts",))
prog = SemiringProgram(semiring="min_plus",
                       init_fn=make_sssp_init(int(pg.part_of[0]),
                                              int(pg.local_of[0])))
plan = PhasedTierPlan.from_graph(pg)
s0, t0 = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                      exchange="phased", tier_plan=plan).run()
tr = Tracer(enabled=True)
s1, t1 = GopherEngine(pg, prog, backend="shard_map", mesh=mesh,
                      exchange="phased", tier_plan=plan, tracer=tr).run()
assert np.array_equal(np.asarray(s0["x"]), np.asarray(s1["x"]))
assert t0.supersteps == t1.supersteps and t0.wire_slots == t1.wire_slots
assert np.array_equal(t0.wire_hist, t1.wire_hist)
assert np.array_equal(t0.phase_hist, t1.phase_hist)
assert tr.balanced
trace = tr.chrome_trace()
validate_chrome_trace(trace)
by_name = {}
for s in tr.spans:
    by_name.setdefault(s.name, s)
assert by_name["run"].depth == 0
assert by_name["phase"].depth == 1
assert by_name["superstep"].depth == 2
for stage in ("sweep", "pack", "exchange", "halt-vote"):
    assert by_name[stage].depth == 3
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# ---------------- metrics feeds ----------------

def test_engine_feeds_metrics(road):
    g, pg = road
    reg = MetricsRegistry()
    eng = GopherEngine(pg, _prog(pg, "cc"), metrics=reg)
    _, t = eng.run()
    snap = reg.snapshot()
    validate_metrics(snap)
    labels = f"{{backend=local,exchange={t.exchange}}}"
    assert snap["counters"][f"engine_runs_total{labels}"] == 1
    assert snap["counters"][f"engine_supersteps_total{labels}"] \
        == t.supersteps
    assert snap["counters"][f"engine_wire_slots_total{labels}"] \
        == t.wire_slots
    assert snap["gauges"][f"engine_partition_imbalance{labels}"] \
        == pytest.approx(imbalance_score(t.local_iters))


def test_telemetry_skew_method(road):
    g, pg = road
    _, t = GopherEngine(pg, _prog(pg, "cc"), exchange="compact").run()
    rep = t.skew()
    assert rep["imbalance"] >= 1.0
    assert 0 <= rep["straggler"] < pg.num_parts
    assert rep["wire"]["send_imbalance"] >= 1.0
    assert rep == skew_report(t)


def test_service_stats_live_metrics(road):
    from repro.serving.service import GraphQueryService
    g, pg = road
    svc = GraphQueryService({"g": pg})
    svc.submit("sssp", "g", [0])
    svc.submit("sssp", "g", [5])
    svc.drain()
    svc.query("sssp", "g", [0])          # exact-cache hit
    s = svc.stats()                      # the Gopher Scope serving report
    assert s["served"] == 3 and s["cache_hits"] == 1
    assert s["cache_hit_rate"] == pytest.approx(1 / 3, abs=1e-3)
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    assert s["imbalance"]["g"] >= 1.0
    assert s["skew"]["g"]["runs"] == 1
    assert s["result_cache"]["hit_rate"] == pytest.approx(1 / 3, abs=1e-3)
    assert svc.stats.summary()["served"] == 3   # attribute API still works
    assert svc.cache.hit_rate() == pytest.approx(1 / 3, abs=1e-3)


def test_tier_profile_drift_metrics(road):
    from repro.core import host_graph_block, update_profile
    from repro.obs import metrics as obs_metrics
    g, pg = road
    reg = MetricsRegistry()
    old = obs_metrics.default_registry()
    obs_metrics.set_default_registry(reg)
    try:
        hb = host_graph_block(pg)
        update_profile(hb, np.zeros((pg.num_parts, pg.num_parts)), rounds=1)
        snap = reg.snapshot()
        assert snap["counters"][
            "tiers_profile_updates_total{profile=wire}"] == 1
        assert snap["gauges"]["tiers_profile_drift{profile=wire}"] > 0
    finally:
        obs_metrics.set_default_registry(old)
