"""Pallas kernel validation: interpret=True vs the pure-jnp oracle, swept over
shapes / semirings / block sizes, plus hypothesis property sweeps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property sweeps need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.gofs.formats import PAD
from repro.kernels import (bin_rows_by_degree, multibin_spmv,
                           semiring_spmv_pallas, semiring_spmv_ref)

SEMIRINGS = ["min_plus", "max_first", "plus_times"]


def _random_ell(rng, v, d, frac_pad=0.3):
    nbr = rng.integers(0, v, (v, d)).astype(np.int32)
    pad = rng.random((v, d)) < frac_pad
    nbr[pad] = PAD
    wgt = rng.uniform(0.1, 2.0, (v, d)).astype(np.float32)
    x = rng.uniform(0.0, 5.0, v).astype(np.float32)
    return x, nbr, wgt


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("v,d,bv", [(64, 8, 16), (100, 16, 32), (257, 24, 64),
                                    (33, 8, 256)])
def test_pallas_matches_ref(semiring, v, d, bv):
    rng = np.random.default_rng(hash((semiring, v, d)) % 2**31)
    x, nbr, wgt = _random_ell(rng, v, d)
    got = semiring_spmv_pallas(jnp.asarray(x), jnp.asarray(nbr),
                               jnp.asarray(wgt), semiring, block_v=bv)
    want = semiring_spmv_ref(jnp.asarray(x), jnp.asarray(nbr),
                             jnp.asarray(wgt), semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_all_pad_rows(semiring):
    """Rows with zero neighbors must produce the ⊕-identity."""
    v = 16
    nbr = np.full((v, 8), PAD, np.int32)
    wgt = np.zeros((v, 8), np.float32)
    x = np.ones(v, np.float32)
    got = np.asarray(semiring_spmv_pallas(
        jnp.asarray(x), jnp.asarray(nbr), jnp.asarray(wgt), semiring, block_v=8))
    ident = {"min_plus": np.inf, "max_first": -np.inf, "plus_times": 0.0}[semiring]
    assert np.all(got == ident)


def test_vmap_over_partitions():
    """The engine vmaps the kernel over the partition axis."""
    rng = np.random.default_rng(0)
    P, v, d = 3, 40, 8
    xs, nbrs, wgts = [], [], []
    for _ in range(P):
        x, nbr, wgt = _random_ell(rng, v, d)
        xs.append(x)
        nbrs.append(nbr)
        wgts.append(wgt)
    xs, nbrs, wgts = map(np.stack, (xs, nbrs, wgts))
    got = jax.vmap(lambda a, b, c: semiring_spmv_pallas(a, b, c, "min_plus",
                                                        block_v=16))(
        jnp.asarray(xs), jnp.asarray(nbrs), jnp.asarray(wgts))
    for p in range(P):
        want = semiring_spmv_ref(jnp.asarray(xs[p]), jnp.asarray(nbrs[p]),
                                 jnp.asarray(wgts[p]), "min_plus")
        np.testing.assert_allclose(np.asarray(got[p]), np.asarray(want),
                                   rtol=1e-6)


@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_multibin_matches_single_bin(semiring):
    """Degree-binned ELL (powerlaw mitigation) must equal the flat sweep."""
    rng = np.random.default_rng(7)
    v = 128
    deg = np.minimum(rng.zipf(1.3, v), 64)          # skewed degrees
    d = int(deg.max())
    nbr = np.full((v, d), PAD, np.int32)
    for i in range(v):
        nbr[i, :deg[i]] = rng.integers(0, v, deg[i])
    wgt = rng.uniform(0.1, 1.0, (v, d)).astype(np.float32)
    x = rng.uniform(0, 3, v).astype(np.float32)
    bins = bin_rows_by_degree(nbr, wgt, boundaries=(4, 16))
    got = multibin_spmv(jnp.asarray(x), bins, v, semiring, backend="jnp")
    want = semiring_spmv_ref(jnp.asarray(x), jnp.asarray(nbr),
                             jnp.asarray(wgt), semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    # padding waste bound: binned cells < flat ELL cells for skewed degrees
    flat_cells = v * d
    bin_cells = sum(b[1].size for b in bins)
    assert bin_cells < flat_cells


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 80), st.integers(1, 12), st.integers(0, 2),
       st.sampled_from(SEMIRINGS))
def test_property_pallas_equals_ref(v, d, seed, semiring):
    rng = np.random.default_rng(seed)
    x, nbr, wgt = _random_ell(rng, v, d)
    got = semiring_spmv_pallas(jnp.asarray(x), jnp.asarray(nbr),
                               jnp.asarray(wgt), semiring, block_v=32)
    want = semiring_spmv_ref(jnp.asarray(x), jnp.asarray(nbr),
                             jnp.asarray(wgt), semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------- flash kernel

def _naive_attn(q, k, v, causal=True, window=None):
    import math
    B, S, H, dh = q.shape
    KV = k.shape[2]
    g = H // KV
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / math.sqrt(dh)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)


@pytest.mark.parametrize("H,KV,window", [(4, 4, None), (4, 2, None), (8, 2, 8)])
def test_flash_kernel_matches_naive(H, KV, window):
    from repro.kernels.flash_attention import flash_attention_pallas
    B, S, dh = 2, 32, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, dh))
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 q_block=8, kv_block=8)
    want = _naive_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flash_kernel_matches_layer_impl():
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.layers import flash_attention
    B, S, H, KV, dh = 1, 64, 4, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, dh))
    got = flash_attention_pallas(q, k, v, q_block=16, kv_block=16)
    want = flash_attention(q, k, v, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- mamba scan

@pytest.mark.parametrize("B,L,D,N,bd", [(2, 16, 8, 4, 4), (1, 24, 16, 8, 16),
                                        (2, 10, 12, 4, 8)])
def test_mamba_scan_kernel_matches_ref(B, L, D, N, bd):
    from repro.kernels.mamba_scan import mamba1_scan_pallas, mamba1_scan_ref
    rng = np.random.default_rng(B * 100 + L)
    x = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, L, D)), jnp.float32)
    Bv = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    Cv = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (D, N)), jnp.float32)
    got = mamba1_scan_pallas(x, dt, Bv, Cv, A, block_d=bd)
    want = mamba1_scan_ref(x, dt, Bv, Cv, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mamba_scan_kernel_matches_mixer_core():
    """The kernel computes the same recurrence the mixer's chunked scan does
    (cross-validated through the step-by-step oracle both are tested against)."""
    from repro.kernels.mamba_scan import mamba1_scan_pallas, mamba1_scan_ref
    rng = np.random.default_rng(0)
    B, L, D, N = 1, 32, 8, 4
    x = jnp.asarray(rng.standard_normal((B, L, D)), jnp.float32) * 0.3
    dt = jnp.asarray(rng.uniform(0.05, 0.3, (B, L, D)), jnp.float32)
    Bv = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    Cv = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 1.5, (D, N)), jnp.float32)
    got = mamba1_scan_pallas(x, dt, Bv, Cv, A, block_d=8)
    want = mamba1_scan_ref(x, dt, Bv, Cv, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
