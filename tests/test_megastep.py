"""Gopher Hot gates: the fused superstep megakernel (kernels.megastep).

Parity contract under test — the same one the exchange stack already
promises: idempotent-⊕ programs (CC/BFS/SSSP, scalar and query-batched)
are BIT-IDENTICAL across the fused route, its Pallas embodiment
(interpret mode on CPU), the resident narrow-phase schedule, and the
staged dense/compact paths; PageRank (⊕ = sum) is allclose. Telemetry's
logical frontier observation (pair_slots / count_hist / messages_sent)
must match the compact path exactly so the tier-profile EWMAs keep
learning from fused runs, while wire_slots/bytes_on_wire are zero.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (GopherEngine, PageRankProgram, PhasedTierPlan,
                        SemiringProgram, graph_block, init_max_vertex,
                        make_sssp_init)
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
from repro.kernels import megastep as mega


@pytest.fixture(scope="module")
def road():
    g = road_grid(10, 11, drop_frac=0.06, seed=3, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    return g, pg


def _source(pg):
    return int(pg.part_of[0]), int(pg.local_of[0])


def _programs(pg):
    sp, sl = _source(pg)
    return {
        "cc": SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
        "sssp": SemiringProgram(semiring="min_plus",
                                init_fn=make_sssp_init(sp, sl)),
    }


# ---------------- auto resolution ----------------

def test_auto_resolves_megastep_on_local(road):
    _, pg = road
    eng = GopherEngine(pg, _programs(pg)["cc"], exchange="auto")
    assert eng.exchange == "megastep"
    # bounded local fixpoints have no fused embodiment: auto keeps dense
    bounded = SemiringProgram(semiring="max_first", init_fn=init_max_vertex,
                              max_local_iters=1)
    assert GopherEngine(pg, bounded, exchange="auto").exchange == "dense"
    # fixed-iteration PageRank fuses; tolerance-halted stays dense
    pr = PageRankProgram(n_global=pg.n_global, num_iters=8)
    assert GopherEngine(pg, pr, exchange="auto").exchange == "megastep"
    pr_tol = PageRankProgram(n_global=pg.n_global, num_iters=8, tol=1e-6)
    assert GopherEngine(pg, pr_tol, exchange="auto").exchange == "dense"


def test_megastep_requires_eligible_program(road):
    _, pg = road
    bounded = SemiringProgram(semiring="max_first", init_fn=init_max_vertex,
                              max_local_iters=1)
    with pytest.raises(AssertionError, match="eligible"):
        GopherEngine(pg, bounded, exchange="megastep")


# ---------------- engine-level parity ----------------

def test_fused_bit_identity_and_telemetry(road):
    _, pg = road
    for name, prog in _programs(pg).items():
        s_ref, t_ref = GopherEngine(pg, prog, exchange="dense").run()
        _, t_cmp = GopherEngine(pg, prog, exchange="compact").run()
        s, t = GopherEngine(pg, prog, exchange="megastep").run()
        assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"])), name
        assert t.supersteps == t_ref.supersteps, name
        assert np.array_equal(t.local_iters, t_ref.local_iters), name
        assert np.array_equal(t.changed_hist, t_ref.changed_hist), name
        # the LOGICAL frontier observation matches compact exactly ...
        assert np.array_equal(t.pair_slots, t_cmp.pair_slots), name
        assert np.array_equal(t.count_hist, t_cmp.count_hist), name
        assert t.messages_sent == t_cmp.messages_sent, name
        # ... but nothing ships through a routed buffer
        assert t.wire_slots == 0 and t.bytes_on_wire == 0, name


def test_pagerank_fused_allclose(road):
    _, pg = road
    prog = PageRankProgram(n_global=pg.n_global, num_iters=15)
    s_ref, t_ref = GopherEngine(pg, prog, exchange="dense").run()
    s, t = GopherEngine(pg, prog, exchange="megastep").run()
    assert t.supersteps == t_ref.supersteps
    np.testing.assert_allclose(np.asarray(s["r"]), np.asarray(s_ref["r"]),
                               rtol=1e-5, atol=1e-7)
    assert t.wire_slots == 0


def test_batched_queries_fused_parity(road):
    from repro.serving.batched import (QUERY_INIT_KEY, BatchedSemiringProgram,
                                       sssp_query_init)
    _, pg = road
    Q = 3
    prog = BatchedSemiringProgram(semiring="min_plus", num_queries=Q)
    extra = {QUERY_INIT_KEY: sssp_query_init(pg, [0, 7, 19])}
    s_ref, t_ref = GopherEngine(pg, prog,
                                exchange="compact").run_queries(extra=extra)
    s, t = GopherEngine(pg, prog,
                        exchange="megastep").run_queries(extra=extra)
    assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"]))
    assert np.array_equal(t.query_supersteps, t_ref.query_supersteps)
    assert np.array_equal(t.pair_slots, t_ref.pair_slots)
    assert t.wire_slots == 0


def test_incremental_resume_rides_fused_route(road):
    """resume=True ships x0/frontier0 through ``extra`` — the merge branch
    of _gb_for_run (run-specific entries layered over the pre-composed
    mcm_* block). Parity vs the dense staged resume, and the quiesced
    resume must still halt in one superstep with zero sweeps."""
    _, pg = road
    sp, sl = _source(pg)
    fix, _ = GopherEngine(pg, SemiringProgram(
        semiring="min_plus", init_fn=make_sssp_init(sp, sl)),
        exchange="dense").run()
    x_fix = np.asarray(fix["x"])
    prog = SemiringProgram(semiring="min_plus", resume=True)
    # invalidate a patch of vertices and re-relax from the stale fixpoint
    x0 = np.where(pg.vmask, x_fix, np.inf).astype(np.float32)
    fr0 = np.zeros_like(pg.vmask)
    x0[1, :8] = np.inf
    fr0[1, :8] = True
    extra = {"x0": x0, "frontier0": fr0}
    s_ref, _ = GopherEngine(pg, prog, exchange="dense").run(extra=extra)
    eng = GopherEngine(pg, prog, exchange="megastep")
    s, t = eng.run(extra=extra)
    assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"]))
    # quiesced resume: one superstep, zero local iterations, state unchanged
    s2, t2 = eng.run(extra={"x0": np.asarray(s["x"]),
                            "frontier0": np.zeros_like(pg.vmask)})
    assert t2.supersteps == 1
    assert t2.local_iters.sum() == 0
    assert np.array_equal(np.asarray(s2["x"]), np.asarray(s["x"]))


def test_checkpointed_run_falls_back_to_staged(road, tmp_path):
    from repro.training.checkpoint import Checkpointer
    _, pg = road
    prog = _programs(pg)["sssp"]
    s_ref, t_ref = GopherEngine(pg, prog, exchange="dense").run()
    eng = GopherEngine(pg, prog, exchange="megastep")
    s, t = eng.run(checkpointer=Checkpointer(str(tmp_path)),
                   checkpoint_every=2)
    assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"]))
    assert t.supersteps == t_ref.supersteps
    assert eng.exchange == "megastep"   # the fallback must not stick


# ---------------- resident narrow-phase mode ----------------

def test_resident_mode_bit_identity(road):
    _, pg = road
    plan = PhasedTierPlan.from_graph(pg)
    for name, prog in _programs(pg).items():
        s_ref, _ = GopherEngine(pg, prog, exchange="dense").run()
        s, t = GopherEngine(pg, prog, exchange="megastep",
                            tier_plan=plan).run()
        assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"])), \
            name
        assert t.wire_slots == 0, name


def test_resident_pallas_kernel_quiescence_early_exit(road):
    """The multi-superstep resident launch (interpret mode on CPU) must
    exit on quiescence well before the iteration bound and land on the
    staged fixpoint bit for bit, with the BSP state contract intact."""
    _, pg = road
    sp, sl = _source(pg)
    prog = SemiringProgram(semiring="min_plus",
                           init_fn=make_sssp_init(sp, sl))
    gb = graph_block(pg)
    cm = mega.compose_mailbox(gb)
    st0 = jax.vmap(prog.init)(gb)
    x = st0["x"].reshape(-1)
    ch = st0["changed_v"].reshape(-1)
    fr = st0["frontier"].reshape(-1)
    x2, ch2, fr2, it, li = mega.resident_megastep_pallas(
        x, ch, fr, cm, "min_plus", max_steps=200, interpret=True)
    s_ref, _ = GopherEngine(pg, prog, exchange="dense").run()
    assert np.array_equal(np.asarray(x2).reshape(pg.num_parts, -1),
                          np.asarray(s_ref["x"]))
    assert int(it) < 200              # quiesced, not bound-limited
    assert not np.asarray(ch2).any()  # ... and the exit state shows it
    assert not np.asarray(fr2).any()


def test_resident_enter_round_suffix_rule():
    B = mega.MEGASTEP_VMEM_BUDGET
    # every band fits -> enter at superstep 0
    assert mega.resident_enter_round([B - 1, B // 2], [4]) == 0
    # only the tail band fits -> enter at its boundary
    assert mega.resident_enter_round([B + 1, B // 2], [4]) == 4
    # a non-monotone profile blocks the earlier fitting band
    assert mega.resident_enter_round([B // 2, B + 1, B // 2], [3, 7]) == 7
    # no suffix fits
    assert mega.resident_enter_round([B // 2, B + 1], [5]) is None


# ---------------- kernel-level parity (Pallas interpret vs jnp oracle) ----

def test_pallas_megastep_matches_oracle(road):
    _, pg = road
    gb = graph_block(pg)
    cm = mega.compose_mailbox(gb)
    for name, prog in _programs(pg).items():
        semiring = prog.semiring
        st0 = jax.vmap(prog.init)(gb)
        x = st0["x"].reshape(-1)
        ch = st0["changed_v"].reshape(-1)
        fr = st0["frontier"].reshape(-1)
        for _ in range(3):   # walk a few supersteps, compare each
            xo, cho, fo, lo = mega.megastep_semiring(
                x, ch, fr, cm, semiring, backend="jnp")
            xp, chp, fp, lp = mega.megastep_semiring_pallas(
                x, ch, fr, cm, semiring, interpret=True)
            assert np.array_equal(np.asarray(xo), np.asarray(xp)), name
            assert np.array_equal(np.asarray(cho), np.asarray(chp)), name
            assert np.array_equal(np.asarray(fo), np.asarray(fp)), name
            assert np.array_equal(np.asarray(lo), np.asarray(lp)), name
            x, ch, fr = xo, cho, fo


def test_engine_dispatches_pallas_backend(road, monkeypatch):
    """Force _default_backend to 'pallas' (interpret on CPU) and run the
    whole engine loop through the megakernel embodiment."""
    _, pg = road
    prog = _programs(pg)["cc"]
    s_ref, t_ref = GopherEngine(pg, prog, exchange="dense").run()
    monkeypatch.setattr(mega, "_default_backend", lambda: "pallas")
    s, t = GopherEngine(pg, prog, exchange="megastep").run()
    assert np.array_equal(np.asarray(s["x"]), np.asarray(s_ref["x"]))
    assert t.supersteps == t_ref.supersteps


# ---------------- composed-mailbox observations ----------------

def test_round_stats_matches_slot_table(road):
    """The einsum contraction must reproduce the slot-table observation
    exactly: pairs[p, j] counts active ob_inv slots p->j (== the compact
    path's active_slots), nsent counts replicated edges in the send set."""
    _, pg = road
    cm = mega.compose_mailbox(graph_block(pg))
    P, cap, n = cm["num_parts"], cm["cap"], cm["n"]
    so = np.asarray(cm["slot_ok"]).reshape(P, P, cap)
    ss = np.asarray(cm["slot_src"]).reshape(P, P, cap)
    eo = np.asarray(cm["edge_ok"])
    es = np.asarray(cm["edge_src"])
    rng = np.random.default_rng(0)
    for changed in [None,
                    rng.random(n) < 0.2,
                    rng.random(n) < 0.8,
                    np.zeros(n, bool),
                    rng.random((n, 3)) < 0.15]:        # batched send set
        pairs, nsent = mega.round_stats(
            None if changed is None else jnp.asarray(changed), cm)
        send_v = (np.ones(n, bool) if changed is None
                  else changed if changed.ndim == 1
                  else changed.any(axis=1))
        ref_pairs = (so & send_v[ss]).sum(axis=2)
        assert np.array_equal(np.asarray(pairs), ref_pairs)
        if changed is None or changed.ndim == 1:
            ref_sent = int((eo & send_v[es]).sum())
        else:   # batched: messages counted per query lane
            ref_sent = int((eo[..., None] & changed[es]).sum())
        assert int(nsent) == ref_sent


def test_service_warm_precompiles_fused_loop(road):
    from repro.serving import GraphQueryService
    _, pg = road
    svc = GraphQueryService({"road": pg}, max_batch=8)
    assert svc.warm("road") >= 1
    r = svc.query("bfs", "road", 0)
    assert r.result[0] == 0.0
