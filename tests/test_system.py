"""End-to-end behaviour tests: the paper's algorithms on the paper's graph
shapes, validated against scipy ground truth, in BOTH execution models
(sub-graph centric Gopher and vertex centric Giraph-baseline)."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csgraph


from repro.gofs import (bfs_grow_partition, hash_partition,
                        powerlaw_social, road_grid, subgraph_balanced_partition,
                        trace_star)
from repro.gofs.formats import partition_graph
from repro.core import meta_diameter, vertex_diameter
from repro.algorithms import blockrank, connected_components, pagerank, sssp


def _gather(pg, per_part):
    """(P, v_max) -> (n,) global order."""
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


GRAPHS = {
    "road": lambda: road_grid(16, 16, drop_frac=0.08, seed=1),
    "social": lambda: powerlaw_social(300, m=4, seed=2),
    "trace": lambda: trace_star(300, n_hubs=4, seed=3),
}
PARTITIONERS = {
    "hash": hash_partition,
    "bfs": bfs_grow_partition,
    "balanced": subgraph_balanced_partition,
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("pname", ["hash", "bfs"])
def test_connected_components_matches_scipy(gname, pname):
    g = GRAPHS[gname]()
    pg = partition_graph(g, PARTITIONERS[pname](g, 4, seed=0), 4)
    ncc_true, lab_true = csgraph.connected_components(g.undirected_csr(),
                                                      directed=False)
    labels, ncc, tele = connected_components(pg, mode="subgraph")
    assert ncc == ncc_true
    ours = _gather(pg, labels)
    # same partition of vertices into components
    for c in range(ncc_true):
        vals = np.unique(ours[lab_true == c])
        assert len(vals) == 1


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_sssp_matches_scipy(gname):
    g = GRAPHS[gname]()
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    src = 0
    d_true = csgraph.shortest_path(g.undirected_csr(), unweighted=True,
                                   indices=[src])[0]
    dist, _ = sssp(pg, src, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    np.testing.assert_allclose(ours[finite], d_true[finite], atol=1e-5)
    assert np.array_equal(np.isfinite(ours), finite)


def test_weighted_sssp():
    g = road_grid(10, 10, drop_frac=0.0, seed=4, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 3, seed=0), 3)
    d_true = csgraph.shortest_path(g.csr().T, indices=[5])[0]  # out-edges
    dist, _ = sssp(pg, 5, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    np.testing.assert_allclose(ours[finite], d_true[finite], rtol=1e-5)


def _pagerank_oracle(g, iters, damping=0.85):
    """float64 power iteration WITH dangling-mass redistribution (the
    engine's — correct — formulation: sinks teleport their rank)."""
    A = g.csr()
    outdeg = g.out_degree.astype(np.float64)
    rr = np.full(g.n, 1.0 / g.n)
    for _ in range(iters):
        contrib = np.where(outdeg > 0, rr / np.maximum(outdeg, 1), 0)
        mass = rr[outdeg == 0].sum()
        rr = (1 - damping) / g.n + damping * (A @ contrib + mass / g.n)
    return rr


def test_pagerank_matches_reference():
    g = powerlaw_social(300, m=4, seed=5)   # dust vertices = dangling sinks
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    r, tele = pagerank(pg, num_iters=30)
    rr = _pagerank_oracle(g, 30)
    # fp32 segment-sum at powerlaw hubs vs float64 reference: relative check
    np.testing.assert_allclose(_gather(pg, r), rr, rtol=1e-2, atol=1e-5)
    assert tele.supersteps == 30
    np.testing.assert_allclose(_gather(pg, r).sum(), 1.0, rtol=1e-4)


def test_pagerank_dangling_mass_conserved():
    """Bugfix regression: directed graph with sinks — ranks must sum to 1
    (dangling mass redistributes via teleport instead of evaporating), and
    the early-halt tolerance is a GLOBAL criterion, so every partition halts
    at the same superstep."""
    rng = np.random.default_rng(11)
    n, ne = 120, 400
    src = rng.integers(15, n, ne)           # vertices [0, 15) are pure sinks
    dst = rng.integers(0, n, ne)
    keep = src != dst
    from repro.gofs.formats import Graph
    g = Graph.from_edges(n, src[keep], dst[keep], directed=True)
    assert (g.out_degree == 0).any()
    pg = partition_graph(g, hash_partition(g, 4, seed=0), 4)
    r, _ = pagerank(pg, num_iters=50)
    np.testing.assert_allclose(_gather(pg, r).sum(), 1.0, rtol=1e-4)
    np.testing.assert_allclose(_gather(pg, r), _pagerank_oracle(g, 50),
                               rtol=1e-3, atol=1e-7)
    # global tol: converges and conserves mass with early halt too
    r2, tele2 = pagerank(pg, num_iters=200, tol=1e-10)
    assert tele2.supersteps < 200
    np.testing.assert_allclose(_gather(pg, r2).sum(), 1.0, rtol=1e-4)


def test_blockrank_converges_to_pagerank_fixpoint():
    g = road_grid(12, 12, drop_frac=0.05, seed=6)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    rb, tele, info = blockrank(pg, tol=1e-9, max_iters=100)
    rr = _pagerank_oracle(g, 200)
    np.testing.assert_allclose(_gather(pg, rb), rr, atol=1e-4)
    assert info["num_meta"] >= pg.num_parts  # at least one block per partition


def test_superstep_reduction_paper_claim():
    """Paper Fig 4(c): sub-graph centric takes FEWER supersteps than vertex
    centric, and is bounded by the meta-graph diameter (+constant)."""
    g = road_grid(20, 20, drop_frac=0.05, seed=7)  # high-diameter graph (RN)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    _, _, t_sub = connected_components(pg, mode="subgraph")
    _, _, t_vert = connected_components(pg, mode="vertex")
    assert t_sub.supersteps <= t_vert.supersteps
    dm = meta_diameter(pg)
    assert t_sub.supersteps <= dm + 3
    dv = vertex_diameter(g)
    assert t_vert.supersteps <= dv + 3
    assert t_vert.supersteps > t_sub.supersteps  # strict on high-diameter RN


def test_shard_map_backend_matches_local():
    g = road_grid(12, 12, drop_frac=0.06, seed=8)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    from repro.core import compat
    mesh = compat.make_mesh((1,), ("parts",))
    lab0, ncc0, t0 = connected_components(pg, mode="subgraph", backend="local")
    lab1, ncc1, t1 = connected_components(pg, mode="subgraph",
                                          backend="shard_map", mesh=mesh)
    assert np.array_equal(lab0, lab1)
    assert ncc0 == ncc1
    assert t0.supersteps == t1.supersteps
    d0, _ = sssp(pg, 3, backend="local")
    d1, _ = sssp(pg, 3, backend="shard_map", mesh=mesh)
    assert np.allclose(d0[pg.vmask], d1[pg.vmask])


def test_bounded_local_iters_still_correct():
    """Straggler mitigation: capping local sweep iterations trades supersteps
    for tail latency but must stay correct (beyond-paper, DESIGN.md §7)."""
    g = road_grid(14, 14, drop_frac=0.05, seed=9)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    ncc_true, _ = csgraph.connected_components(g.undirected_csr(), directed=False)
    _, ncc_full, t_full = connected_components(pg, mode="subgraph")
    _, ncc_cap, t_cap = connected_components(pg, mode="subgraph",
                                             max_local_iters=3)
    assert ncc_full == ncc_cap == ncc_true
    assert t_cap.supersteps >= t_full.supersteps


def test_bsp_checkpoint_restart(tmp_path):
    """Fault tolerance: kill the BSP run mid-way, restart from the last
    committed superstep snapshot, converge to the identical answer."""
    from repro.core import GopherEngine, SemiringProgram, init_max_vertex
    from repro.training.checkpoint import Checkpointer
    g = road_grid(16, 16, drop_frac=0.05, seed=11)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)

    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    ref_state, ref_tele = GopherEngine(pg, prog).run()

    # run with per-2-superstep checkpoints, but cap supersteps to "fail" early
    ck = Checkpointer(str(tmp_path))
    eng_fail = GopherEngine(pg, prog, max_supersteps=3)
    eng_fail.run(checkpointer=ck, checkpoint_every=2)
    assert ck.latest_step() is not None
    assert ck.latest_step() < ref_tele.supersteps  # genuinely mid-run

    # restart and finish
    eng2 = GopherEngine(pg, prog)
    state2, tele2 = eng2.run(checkpointer=ck, checkpoint_every=2, resume=True)
    assert np.array_equal(np.asarray(state2["x"]), np.asarray(ref_state["x"]))
    assert tele2.supersteps == ref_tele.supersteps


def test_checkpointed_run_telemetry_and_block_reuse(tmp_path):
    """Regression: checkpointed runs must reuse the engine's cached device
    graph block (not rebuild a second copy) and report REAL telemetry —
    message counts and per-superstep changed history, like normal runs."""
    from repro.core import GopherEngine, SemiringProgram, init_max_vertex
    from repro.training.checkpoint import Checkpointer
    g = road_grid(14, 14, drop_frac=0.05, seed=12)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    ref_state, ref_tele = GopherEngine(pg, prog).run()

    eng = GopherEngine(pg, prog)
    gb_before = eng._graph_block()
    state, tele = eng.run(checkpointer=Checkpointer(str(tmp_path)),
                          checkpoint_every=3)
    assert eng._graph_block() is gb_before        # cached block reused
    assert np.array_equal(np.asarray(state["x"]), np.asarray(ref_state["x"]))
    assert tele.supersteps == ref_tele.supersteps
    assert tele.messages_sent == ref_tele.messages_sent >= 0
    assert np.array_equal(tele.changed_hist, ref_tele.changed_hist)
    assert np.array_equal(tele.local_iters, ref_tele.local_iters)
