"""End-to-end behaviour tests: the paper's algorithms on the paper's graph
shapes, validated against scipy ground truth, in BOTH execution models
(sub-graph centric Gopher and vertex centric Giraph-baseline)."""
import numpy as np
import pytest
import scipy.sparse.csgraph as csgraph

import jax

from repro.gofs import (GoFSStore, bfs_grow_partition, hash_partition,
                        powerlaw_social, road_grid, subgraph_balanced_partition,
                        trace_star)
from repro.gofs.formats import partition_graph
from repro.core import meta_diameter, vertex_diameter
from repro.algorithms import blockrank, connected_components, pagerank, sssp


def _gather(pg, per_part):
    """(P, v_max) -> (n,) global order."""
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


GRAPHS = {
    "road": lambda: road_grid(16, 16, drop_frac=0.08, seed=1),
    "social": lambda: powerlaw_social(300, m=4, seed=2),
    "trace": lambda: trace_star(300, n_hubs=4, seed=3),
}
PARTITIONERS = {
    "hash": hash_partition,
    "bfs": bfs_grow_partition,
    "balanced": subgraph_balanced_partition,
}


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("pname", ["hash", "bfs"])
def test_connected_components_matches_scipy(gname, pname):
    g = GRAPHS[gname]()
    pg = partition_graph(g, PARTITIONERS[pname](g, 4, seed=0), 4)
    ncc_true, lab_true = csgraph.connected_components(g.undirected_csr(),
                                                      directed=False)
    labels, ncc, tele = connected_components(pg, mode="subgraph")
    assert ncc == ncc_true
    ours = _gather(pg, labels)
    # same partition of vertices into components
    for c in range(ncc_true):
        vals = np.unique(ours[lab_true == c])
        assert len(vals) == 1


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_sssp_matches_scipy(gname):
    g = GRAPHS[gname]()
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    src = 0
    d_true = csgraph.shortest_path(g.undirected_csr(), unweighted=True,
                                   indices=[src])[0]
    dist, _ = sssp(pg, src, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    np.testing.assert_allclose(ours[finite], d_true[finite], atol=1e-5)
    assert np.array_equal(np.isfinite(ours), finite)


def test_weighted_sssp():
    g = road_grid(10, 10, drop_frac=0.0, seed=4, weighted=True)
    pg = partition_graph(g, bfs_grow_partition(g, 3, seed=0), 3)
    d_true = csgraph.shortest_path(g.csr().T, indices=[5])[0]  # out-edges
    dist, _ = sssp(pg, 5, mode="subgraph")
    ours = _gather(pg, dist)
    finite = np.isfinite(d_true)
    np.testing.assert_allclose(ours[finite], d_true[finite], rtol=1e-5)


def test_pagerank_matches_reference():
    g = powerlaw_social(300, m=4, seed=5)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    r, tele = pagerank(pg, num_iters=30)
    A = g.csr()
    outdeg = g.out_degree.astype(np.float64)
    rr = np.full(g.n, 1.0 / g.n)
    for _ in range(30):
        contrib = np.where(outdeg > 0, rr / np.maximum(outdeg, 1), 0)
        rr = 0.15 / g.n + 0.85 * (A @ contrib)
    # fp32 segment-sum at powerlaw hubs vs float64 reference: relative check
    np.testing.assert_allclose(_gather(pg, r), rr, rtol=1e-2, atol=1e-5)
    assert tele.supersteps == 30


def test_blockrank_converges_to_pagerank_fixpoint():
    g = road_grid(12, 12, drop_frac=0.05, seed=6)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    rb, tele, info = blockrank(pg, tol=1e-9, max_iters=100)
    A = g.csr()
    outdeg = g.out_degree.astype(np.float64)
    rr = np.full(g.n, 1.0 / g.n)
    for _ in range(200):
        contrib = np.where(outdeg > 0, rr / np.maximum(outdeg, 1), 0)
        rr = 0.15 / g.n + 0.85 * (A @ contrib)
    np.testing.assert_allclose(_gather(pg, rb), rr, atol=1e-4)
    assert info["num_meta"] >= pg.num_parts  # at least one block per partition


def test_superstep_reduction_paper_claim():
    """Paper Fig 4(c): sub-graph centric takes FEWER supersteps than vertex
    centric, and is bounded by the meta-graph diameter (+constant)."""
    g = road_grid(20, 20, drop_frac=0.05, seed=7)  # high-diameter graph (RN)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    _, _, t_sub = connected_components(pg, mode="subgraph")
    _, _, t_vert = connected_components(pg, mode="vertex")
    assert t_sub.supersteps <= t_vert.supersteps
    dm = meta_diameter(pg)
    assert t_sub.supersteps <= dm + 3
    dv = vertex_diameter(g)
    assert t_vert.supersteps <= dv + 3
    assert t_vert.supersteps > t_sub.supersteps  # strict on high-diameter RN


def test_shard_map_backend_matches_local():
    g = road_grid(12, 12, drop_frac=0.06, seed=8)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    from repro.core import compat
    mesh = compat.make_mesh((1,), ("parts",))
    lab0, ncc0, t0 = connected_components(pg, mode="subgraph", backend="local")
    lab1, ncc1, t1 = connected_components(pg, mode="subgraph",
                                          backend="shard_map", mesh=mesh)
    assert np.array_equal(lab0, lab1)
    assert ncc0 == ncc1
    assert t0.supersteps == t1.supersteps
    d0, _ = sssp(pg, 3, backend="local")
    d1, _ = sssp(pg, 3, backend="shard_map", mesh=mesh)
    assert np.allclose(d0[pg.vmask], d1[pg.vmask])


def test_bounded_local_iters_still_correct():
    """Straggler mitigation: capping local sweep iterations trades supersteps
    for tail latency but must stay correct (beyond-paper, DESIGN.md §7)."""
    g = road_grid(14, 14, drop_frac=0.05, seed=9)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    ncc_true, _ = csgraph.connected_components(g.undirected_csr(), directed=False)
    _, ncc_full, t_full = connected_components(pg, mode="subgraph")
    _, ncc_cap, t_cap = connected_components(pg, mode="subgraph",
                                             max_local_iters=3)
    assert ncc_full == ncc_cap == ncc_true
    assert t_cap.supersteps >= t_full.supersteps


def test_bsp_checkpoint_restart(tmp_path):
    """Fault tolerance: kill the BSP run mid-way, restart from the last
    committed superstep snapshot, converge to the identical answer."""
    from repro.core import GopherEngine, SemiringProgram, init_max_vertex
    from repro.training.checkpoint import Checkpointer
    g = road_grid(16, 16, drop_frac=0.05, seed=11)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)

    prog = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    ref_state, ref_tele = GopherEngine(pg, prog).run()

    # run with per-2-superstep checkpoints, but cap supersteps to "fail" early
    ck = Checkpointer(str(tmp_path))
    eng_fail = GopherEngine(pg, prog, max_supersteps=3)
    eng_fail.run(checkpointer=ck, checkpoint_every=2)
    assert ck.latest_step() is not None
    assert ck.latest_step() < ref_tele.supersteps  # genuinely mid-run

    # restart and finish
    eng2 = GopherEngine(pg, prog)
    state2, tele2 = eng2.run(checkpointer=ck, checkpoint_every=2, resume=True)
    assert np.array_equal(np.asarray(state2["x"]), np.asarray(ref_state["x"]))
    assert tele2.supersteps == ref_tele.supersteps
