"""Gopher Delta: temporal GoFS (edge-delta batches, versioned store),
frontier-driven incremental re-convergence (bit-identical to cold runs on
both backends), frontier-masked kernels, and version-keyed serving caches."""
import numpy as np
import pytest
import scipy.sparse as sp

import jax.numpy as jnp

from repro.algorithms import (bfs, connected_components,
                              incremental_bfs,
                              incremental_connected_components,
                              incremental_sssp, sssp)
from repro.core import GopherEngine, SemiringProgram, compat
from repro.gofs import (EdgeDelta, TemporalStore, apply_delta,
                        bfs_grow_partition, powerlaw_social, road_grid)
from repro.gofs.formats import PAD, Graph, partition_graph
from repro.kernels import ops


def _gather(pg, per_part):
    out = np.zeros(pg.n_global, per_part.dtype)
    for p in range(pg.num_parts):
        m = pg.vmask[p]
        out[pg.global_id[p][m]] = per_part[p][m]
    return out


def _global_csr(pg):
    """Reassemble the global in-edge CSR from the partitioned layout (local
    ELL + remote edges) — the semantic content apply_delta must preserve."""
    rows, cols, vals = [], [], []
    for p in range(pg.num_parts):
        vv, jj = np.nonzero(pg.nbr[p] != PAD)
        keep = pg.vmask[p][vv]
        vv, jj = vv[keep], jj[keep]
        rows.append(pg.global_id[p][vv])
        cols.append(pg.global_id[p][pg.nbr[p][vv, jj]])
        vals.append(pg.wgt[p][vv, jj])
        m = pg.re_src[p] != PAD
        rows.append(pg.global_id[pg.re_dst_part[p][m], pg.re_dst_local[p][m]])
        cols.append(pg.global_id[p][pg.re_src[p][m]])
        vals.append(pg.re_wgt[p][m])
    return sp.csr_matrix((np.concatenate(vals),
                          (np.concatenate(rows), np.concatenate(cols))),
                         shape=(pg.n_global, pg.n_global))


def _edge_list(g):
    a = g.csr().tocoo()           # row v = dst, col = src
    return a.col, a.row, a.data.astype(np.float32)


@pytest.fixture(scope="module")
def road():
    g = road_grid(22, 22, drop_frac=0.08, seed=3)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    return g, pg


# ---------------- apply_delta vs full GoFS rebuild ----------------

def test_apply_delta_matches_full_rebuild(road):
    g, pg0 = road
    rng = np.random.default_rng(0)
    n = g.n
    iu = rng.integers(0, n, 40)
    iv = rng.integers(0, n, 40)
    keep = iu != iv
    iu, iv = iu[keep], iv[keep]
    iw = rng.uniform(1.0, 5.0, iu.size).astype(np.float32)
    res = apply_delta(pg0, EdgeDelta.inserts(iu, iv, iw), directed=False)
    assert res.pg.version == 1
    assert res.stats["inserted"] + res.stats["weight_updated"] == 2 * iu.size

    src0, dst0, w0 = _edge_list(g)
    g1 = Graph.from_edges(n, np.concatenate([src0, iu]),
                          np.concatenate([dst0, iv]),
                          np.concatenate([w0, iw]), directed=False)
    pg1_cold = partition_graph(g1, bfs_grow_partition(g, 4, seed=0), 4)
    assert (_global_csr(res.pg) != _global_csr(pg1_cold)).nnz == 0
    # sub-graph structure rediscovered where topology changed
    assert np.array_equal(np.sort(res.pg.num_subgraphs),
                          np.sort(pg1_cold.num_subgraphs))
    # dirty seeds: exactly the inserted sources (both directions, undirected)
    marked = {int(res.pg.global_id[p][v])
              for p, v in zip(*np.nonzero(res.dirty_insert))}
    assert marked == set(iu.tolist()) | set(iv.tolist())


def test_apply_delta_removals_and_weight_updates(road):
    g, pg0 = road
    src0, dst0, w0 = _edge_list(g)
    und = src0 < dst0
    pick = np.flatnonzero(und)[:17]
    res = apply_delta(pg0, EdgeDelta.removes(src0[pick], dst0[pick]),
                      directed=False)
    assert res.stats["removed"] == 2 * pick.size
    assert res.stats["remove_missed"] == 0
    a1 = _global_csr(res.pg)
    assert a1.nnz == g.nnz - 2 * pick.size
    # re-inserting one removed edge with a higher-then-lower weight applies
    # the MIN duplicate policy and recycles the freed storage
    u, v = int(src0[pick[0]]), int(dst0[pick[0]])
    res2 = apply_delta(res.pg, EdgeDelta.inserts([u], [v], [9.0]))
    res3 = apply_delta(res2.pg, EdgeDelta.inserts([u], [v], [2.0]))
    a3 = _global_csr(res3.pg)
    assert a3[v, u] == 2.0 and a3[u, v] == 2.0
    assert res3.pg.version == 3
    # removing a non-existent edge is counted, not fatal
    res4 = apply_delta(res3.pg, EdgeDelta.removes([u], [u + 1 if u + 1 != v
                                                        else u + 2]))
    assert res4.stats["remove_missed"] >= 1


def test_out_degree_tracks_deltas(road):
    g, pg0 = road
    rng = np.random.default_rng(1)
    iu = rng.integers(0, g.n, 25)
    iv = (iu + 37) % g.n
    res = apply_delta(pg0, EdgeDelta.inserts(iu, iv), directed=False)
    src0, dst0, w0 = _edge_list(g)
    g1 = Graph.from_edges(g.n, np.concatenate([src0, iu]),
                          np.concatenate([dst0, iv]),
                          np.concatenate([w0, np.ones(iu.size, np.float32)]),
                          directed=False)
    assert np.array_equal(_gather(res.pg, res.pg.out_degree),
                          g1.out_degree)


# ---------------- versioned store ----------------

def test_temporal_store_roundtrip(tmp_path, road):
    g, pg0 = road
    st = TemporalStore(str(tmp_path))
    st.build("g", g, bfs_grow_partition(g, 4, seed=0), 4)
    assert st.latest_version("g") == 0
    d1 = EdgeDelta.inserts([0, 5], [99, 200])
    d2 = EdgeDelta.removes([0], [99])
    assert st.append_delta("g", d1) == 1
    assert st.append_delta("g", d2) == 2
    pg2 = st.materialize("g")
    assert pg2.version == 2
    # replay == in-memory chain
    mem = apply_delta(apply_delta(pg0, d1).pg, d2).pg
    assert (_global_csr(pg2) != _global_csr(mem)).nnz == 0
    # historical version still reachable
    pg1 = st.materialize("g", version=1)
    assert pg1.version == 1
    assert (_global_csr(pg1) != _global_csr(apply_delta(pg0, d1).pg)).nnz == 0


# ---------------- incremental == cold, bit-identical, both backends ----------

@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_incremental_insert_bit_identical(backend, road):
    g, pg0 = road
    mesh = compat.make_mesh((1,), ("parts",)) if backend == "shard_map" else None
    rng = np.random.default_rng(2)
    num = max(1, (g.nnz // 2) // 100)      # the 1% batch of the issue spec
    iu = rng.integers(0, g.n, num)
    iv = rng.integers(0, g.n, num)
    keep = iu != iv
    res = apply_delta(pg0, EdgeDelta.inserts(iu[keep], iv[keep]),
                      directed=False)
    pg1 = res.pg

    lab_prev, _, _ = connected_components(pg0, backend=backend, mesh=mesh)
    lab_cold, ncc_cold, _ = connected_components(pg1, backend=backend,
                                                 mesh=mesh)
    lab_inc, ncc_inc, t_inc = incremental_connected_components(
        pg1, lab_prev, res, backend=backend, mesh=mesh)
    assert np.array_equal(lab_cold, lab_inc) and ncc_cold == ncc_inc

    d_prev, _ = bfs(pg0, 3, backend=backend, mesh=mesh)
    d_cold, t_cold = bfs(pg1, 3, backend=backend, mesh=mesh)
    d_inc, t_inc = incremental_bfs(pg1, 3, d_prev, res, backend=backend,
                                   mesh=mesh)
    assert np.array_equal(d_cold, d_inc)
    # the incremental run did less local work than the cold run
    assert t_inc.local_iters.sum() < t_cold.local_iters.sum()


@pytest.mark.parametrize("backend", ["local", "shard_map"])
def test_incremental_removal_bit_identical(backend):
    g = road_grid(18, 18, drop_frac=0.04, seed=5, weighted=True)
    pg0 = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    mesh = compat.make_mesh((1,), ("parts",)) if backend == "shard_map" else None
    src0, dst0, _ = _edge_list(g)
    und = np.flatnonzero(src0 < dst0)
    rng = np.random.default_rng(6)
    pick = rng.choice(und, 15, replace=False)
    delta = EdgeDelta.of(insert_src=[1, 2], insert_dst=[200, 250],
                         insert_wgt=[2.5, 4.0],
                         remove_src=src0[pick], remove_dst=dst0[pick])
    res = apply_delta(pg0, delta, directed=False)
    pg1 = res.pg

    d_prev, _ = sssp(pg0, 0)
    d_cold, _ = sssp(pg1, 0, backend=backend, mesh=mesh)
    d_inc, _ = incremental_sssp(pg1, 0, d_prev, res, backend=backend,
                                mesh=mesh)
    assert np.array_equal(d_cold, d_inc)

    lab_prev, _, _ = connected_components(pg0)
    lab_cold, ncc_cold, _ = connected_components(pg1, backend=backend,
                                                 mesh=mesh)
    lab_inc, ncc_inc, _ = incremental_connected_components(
        pg1, lab_prev, res, backend=backend, mesh=mesh)
    assert np.array_equal(lab_cold, lab_inc) and ncc_cold == ncc_inc


def test_incremental_noop_delta_halts_immediately(road):
    """A delta that changes nothing reachable quiesces in one superstep."""
    g, pg0 = road
    src0, dst0, w0 = _edge_list(g)
    # re-insert an existing edge with its existing weight: weight_update no-op
    res = apply_delta(pg0, EdgeDelta.inserts([src0[0]], [dst0[0]],
                                             [float(w0[0])]))
    d_prev, _ = bfs(pg0, 3)
    d_inc, tele = incremental_bfs(res.pg, 3, d_prev, res)
    assert np.array_equal(d_inc, d_prev)
    assert tele.supersteps <= 2
    assert tele.local_iters.sum() <= pg0.num_parts  # no real sweep work


# ---------------- frontier-masked kernels ----------------

@pytest.mark.parametrize("semiring", ["min_plus", "max_first"])
def test_frontier_sweep_matches_full_on_active_rows(semiring):
    rng = np.random.default_rng(0)
    v, d = 64, 8
    nbr = rng.integers(0, v, (v, d)).astype(np.int32)
    nbr[rng.random((v, d)) < 0.3] = PAD
    wgt = rng.uniform(0.1, 2.0, (v, d)).astype(np.float32)
    x = rng.uniform(0.0, 5.0, v).astype(np.float32)
    frontier = rng.random(v) < 0.25
    y_full = ops.semiring_spmv(jnp.asarray(x), jnp.asarray(nbr),
                               jnp.asarray(wgt), semiring, backend="jnp")
    y_m, act = ops.semiring_spmv_frontier(
        jnp.asarray(x), jnp.asarray(frontier), jnp.asarray(nbr),
        jnp.asarray(wgt), semiring, backend="jnp")
    act = np.asarray(act)
    valid = nbr != PAD
    act_ref = np.any(valid & frontier[np.where(valid, nbr, 0)], axis=1)
    assert np.array_equal(act, act_ref)
    ident = np.inf if semiring == "min_plus" else -np.inf
    assert np.array_equal(np.asarray(y_m)[act], np.asarray(y_full)[act])
    assert np.all(np.asarray(y_m)[~act] == ident)
    # pallas interpret path agrees with the jnp oracle
    y_p, act_p = ops.semiring_spmv_frontier(
        jnp.asarray(x), jnp.asarray(frontier), jnp.asarray(nbr),
        jnp.asarray(wgt), semiring, backend="pallas", block_v=16)
    assert np.array_equal(np.asarray(y_p), np.asarray(y_m))
    assert np.array_equal(np.asarray(act_p), act)


@pytest.mark.parametrize("semiring", ["min_plus", "max_first"])
def test_binned_frontier_sweep_matches_full(semiring):
    g = powerlaw_social(400, m=4, seed=2)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)
    from repro.core.engine import graph_block
    gb = graph_block(pg)
    rng = np.random.default_rng(3)
    Q = 3
    x = jnp.asarray(rng.uniform(0, 5, (pg.v_max, Q)).astype(np.float32))
    f = jnp.asarray(rng.random((pg.v_max, Q)) < 0.3)
    for p in range(pg.num_parts):
        y_full = ops.binned_ell_spmv_multi(
            x, gb["nbr_lo"][p], gb["wgt_lo"][p], gb["adj_hub_idx"][p],
            gb["adj_hub_nbr"][p], gb["adj_hub_wgt"][p], semiring)
        y_m = ops.binned_ell_spmv_multi_frontier(
            x, f, gb["nbr_lo"][p], gb["wgt_lo"][p], gb["adj_hub_idx"][p],
            gb["adj_hub_nbr"][p], gb["adj_hub_wgt"][p], semiring)
        valid = np.asarray(gb["nbr"][p]) != PAD
        fq = np.asarray(f)
        act = np.any(valid[:, :, None]
                     & fq[np.where(valid, np.asarray(gb["nbr"][p]), 0), :],
                     axis=1)
        ident = np.inf if semiring == "min_plus" else -np.inf
        assert np.array_equal(np.asarray(y_m)[act], np.asarray(y_full)[act])
        assert np.all(np.asarray(y_m)[~act] == ident)


def test_frontier_quiesced_partition_runs_zero_sweeps(road):
    """Engine-level VoteToHalt: once converged, a re-run seeded with an
    empty frontier must do zero local iterations and halt in one superstep."""
    g, pg = road
    d_prev, _ = bfs(pg, 3)
    prog = SemiringProgram(semiring="min_plus", resume=True)
    eng = GopherEngine(pg, prog)
    x0 = np.where(pg.vmask, d_prev, np.inf).astype(np.float32)
    state, tele = eng.run(extra={
        "x0": x0, "frontier0": np.zeros_like(pg.vmask)})
    assert tele.supersteps == 1
    assert tele.local_iters.sum() == 0
    assert np.array_equal(np.asarray(state["x"]), x0)


# ---------------- serving: version-keyed invalidation ----------------

def test_service_version_keyed_cache_invalidation(road):
    from repro.serving import GraphQueryService
    g, pg = road
    svc = GraphQueryService({"road": pg}, max_batch=8)
    svc.enable_landmarks("road", num_landmarks=4)
    r1 = svc.query("bfs", "road", 0)
    assert svc.query("bfs", "road", 0).cached
    lm_v0 = svc.landmark_caches["road"].graph_version

    svc.apply_delta("road", EdgeDelta.inserts([0], [g.n - 1]),
                    rebuild_landmarks=True)
    assert svc.graphs["road"].version == 1
    # stale entries evicted eagerly; fresh query recomputed on the new graph
    r2 = svc.query("bfs", "road", 0)
    assert not r2.cached
    assert r2.result[g.n - 1] == 1.0
    assert r1.result[g.n - 1] != 1.0
    # landmark tier rebuilt at the new version
    assert svc.landmark_caches["road"].graph_version == 1 > lm_v0 == 0
    assert svc.cache.stats()["invalidations"] >= 1
    # the same query at the new version is cached independently
    assert svc.query("bfs", "road", 0).cached
