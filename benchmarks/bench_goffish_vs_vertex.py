"""Paper Fig 4(a) + 4(c): makespan and superstep counts, Gopher (sub-graph
centric) vs the vertex-centric baseline (our Giraph stand-in), for Connected
Components / SSSP / PageRank on RN / TR / LJ analogues."""
from __future__ import annotations

from repro.algorithms import connected_components, pagerank, sssp
from benchmarks.common import get_pg, emit, timed


def run():
    rows = []
    for ds in ("RN", "TR", "LJ"):
        g, pg = get_pg(ds)
        for algo, fn in (
            ("cc", lambda m: connected_components(pg, mode=m)),
            ("sssp", lambda m: sssp(pg, 0, mode=m)),
            ("pagerank", lambda m: pagerank(pg, num_iters=30)),
        ):
            for mode in ("subgraph", "vertex"):
                if algo == "pagerank" and mode == "vertex":
                    continue  # identical program; Gopher "simulates" it (paper §5.3)
                out, dt = timed(fn, mode, warmup=True)
                tele = out[-1]
                emit(f"fig4a_makespan_{algo}_{ds}_{mode}", dt,
                     f"supersteps={tele.supersteps}")
                rows.append((ds, algo, mode, dt, tele.supersteps))
    # paper claim check: sub-graph supersteps <= vertex supersteps
    by = {}
    for ds, algo, mode, dt, ss in rows:
        by.setdefault((ds, algo), {})[mode] = ss
    for (ds, algo), m in by.items():
        if "subgraph" in m and "vertex" in m:
            assert m["subgraph"] <= m["vertex"], (ds, algo, m)
    return rows


if __name__ == "__main__":
    run()
