"""Paper Fig 4(b): graph loading time — GoFS partitioned slice load (each
worker reads exactly its partition, no shuffle) vs an HDFS-style monolithic
load (read the whole edge list, then shuffle/partition at load time)."""
from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import NUM_PARTS, emit, timed
from repro.gofs import GoFSStore
from repro.gofs.formats import partition_graph


from repro.gofs import hash_partition, powerlaw_social, road_grid, trace_star

# load-bench graphs are LARGER than the compute-bench ones: the paper's Fig 4b
# effect (layout beats shuffle) needs build cost to dominate file-open noise.
# hash partitioning (what HDFS does) keeps the host-side build bounded.
LOAD_DATASETS = {
    "RN": lambda: road_grid(300, 300, drop_frac=0.03, seed=1),   # 90k
    "TR": lambda: trace_star(40_000, n_hubs=8, seed=2),
    "LJ": lambda: powerlaw_social(40_000, m=5, seed=3),
}


def run():
    rows = []
    with tempfile.TemporaryDirectory() as td:
        store = GoFSStore(os.path.join(td, "gofs"))
        for ds in ("RN", "TR", "LJ"):
            g = LOAD_DATASETS[ds]()
            assign = hash_partition(g, NUM_PARTS, seed=0)
            store.build(ds, g, assign, NUM_PARTS)  # write-once (not timed)
            # monolithic baseline file: flat edge list (what HDFS hands you)
            deg = np.diff(g.indptr)
            dst = np.repeat(np.arange(g.n, dtype=np.int64), deg)
            flat = os.path.join(td, f"{ds}.edges.npz")
            np.savez(flat, src=g.indices, dst=dst, w=g.weights)

            # the paper's Fig 4b metric is PER-WORKER load wall-clock: with
            # the GoFS layout a worker reads exactly its partition's slices;
            # without it (HDFS), every worker must consume the global edge
            # list to find/build its partition. Workers load in parallel on a
            # cluster, so the comparable number is the slowest single worker.
            def load_gofs_worker(p):
                return store.load_partition(ds, p)

            def load_monolithic_worker():
                with np.load(flat) as z:
                    src, dst_, w = z["src"], z["dst"], z["w"]
                from repro.gofs.formats import Graph
                g2 = Graph.from_edges(g.n, src, dst_, weights=w, directed=True)
                return partition_graph(g2, assign, NUM_PARTS)

            t_gofs = max(timed(load_gofs_worker, p, repeats=2)[1]
                         for p in range(NUM_PARTS))
            _, t_mono = timed(load_monolithic_worker, repeats=2)
            emit(f"fig4b_load_{ds}_gofs_worker", t_gofs,
                 f"speedup={t_mono/t_gofs:.1f}x")
            emit(f"fig4b_load_{ds}_monolithic_worker", t_mono, "")
            rows.append((ds, t_gofs, t_mono))
            assert t_gofs < t_mono, (ds, t_gofs, t_mono)
    return rows


if __name__ == "__main__":
    run()
