"""Gopher Wire/Mesh/Phases: communication volume of the superstep exchange.

Scenario (the RN-analogue incremental workload): a converged CC/BFS/SSSP
fixpoint on the road network at version k, a 1% edge-insert batch arrives,
and the frontier-seeded incremental restart re-converges on version k+1.
Six wire disciplines are measured:

  dense     every partition pair's full cap-slot row, every superstep — the
            physical buffer geometry AND the parity oracle
  compact   frontier-compacted protocol payload (PR 3): modeled bytes track
            the frontier, physical buffers stay dense
  tiered    Gopher Mesh: capacity-tiered PHYSICAL buffers — the profile
            taught by version k's runs puts quiet pairs in width-1 cold /
            cap/8 warm tiers, so the geometry the exchange actually routes
            tracks the frontier too
  auto      the engine default: the Gopher Hot MEGASTEP fused route on
            local (one kernel launch per superstep, nothing on the wire),
            tiered on multi-device shard_map
  phased    Gopher Phases: frontier-PHASED tier schedules — one segmented
            BSP loop per frontier band, so a SINGLE run's geometry rides
            the contraction (per-phase wire histograms land in the
            artifact)

The version-k flow teaches the per-pair traffic profile and the
changed-histogram EWMA exactly as a production deployment would: the
converged cold run plus one quiesced resume feed
core.tiers.update_profile / update_changed_profile, and apply_delta
pre-announces the delta's dirty frontier (warm floor bounded by the
expected superstep horizon).

Recorded per (algo, mode): total exchanged slots, bytes-on-wire,
per-superstep wire/changed histograms, wall time — with results asserted
BIT-IDENTICAL across modes on both backends, the tiered run asserted
SPILL-FREE, and its per-round physical geometry asserted <= 25% of the
dense P²·cap (the Gopher Mesh acceptance gate; CI runs this file on main).
The Gopher Hot gates hold auto's megastep preference to its claim: warm
head-to-head aggregate wall-clock at dense parity (within the single-core
noise floor), the cc cold run beaten OUTRIGHT, and zero wire slots.
The COLD-PLAN scenario (cold_phased_scenario) gates Gopher Phases: on a
fresh-replica block with no taught pair profile, the phased run must land
<= 40% of dense — the band the static plan only reaches warm. A tier-churn
scenario (hotspot migrating across partition pairs over 10 versions)
records escalation counts and bytes-vs-dense as the profile chases the
load. Writes BENCH_comm.json.
"""
from __future__ import annotations

import numpy as np


def _teach_profile(pg, hb, prog_cold, semiring, pairs: bool = True):
    """Version-k history: one converged cold run + one quiesced resume,
    folded into the host block's wire_ewma (pairs=True) and its
    changed-histogram EWMA. ``pairs=False`` models a FRESH REPLICA that
    never learned the per-pair profile — only the run-shape history the
    phased plans ride — the cold-plan scenario. Returns the converged
    state."""
    from repro.core import (GopherEngine, SemiringProgram, device_block,
                            update_changed_profile, update_profile)
    gbd = device_block(hb)
    state, tele = GopherEngine(pg, prog_cold, gb=gbd,
                               exchange="compact").run()
    if pairs:
        update_profile(hb, tele.pair_slots, tele.pair_rounds)
    update_changed_profile(hb, tele.count_hist)
    ident = np.inf if semiring == "min_plus" else -np.inf
    x0 = np.where(pg.vmask, np.asarray(state["x"], np.float32), ident)
    prog_res = SemiringProgram(semiring=semiring, resume=True)
    _, tq = GopherEngine(pg, prog_res, gb=gbd, exchange="compact").run(
        extra={"x0": x0, "frontier0": np.zeros_like(pg.vmask)})
    if pairs:
        update_profile(hb, tq.pair_slots, tq.pair_rounds)
    update_changed_profile(hb, tq.count_hist)
    return np.asarray(state["x"])


def _delta_1pct(g, pg0, hb, weighted, seed=7):
    """The RN-analogue 1% edge-insert batch, applied with the zero-repack
    block path."""
    from benchmarks.bench_incremental import _reopened_edges
    from repro.gofs import EdgeDelta, apply_delta
    num_ins = max(1, (g.nnz // 2) // 100)          # the 1% batch
    iu, iv = _reopened_edges(g, 100, 100, num_ins, seed=seed)
    iw = (np.random.default_rng(8).uniform(5.0, 10.0, iu.size)
          .astype(np.float32) if weighted else None)
    return apply_delta(pg0, EdgeDelta.inserts(iu, iv, iw),
                       directed=False, block=hb)


def run(write_json: bool = True):
    from benchmarks.common import NUM_PARTS, emit, get_pg, timed, \
        write_bench_json
    from repro.core import (GopherEngine, SemiringProgram, TierPlan, compat,
                            device_block, host_graph_block, init_max_vertex,
                            make_sssp_init)
    from repro.gofs import EdgeDelta, apply_delta, bfs_grow_partition, \
        road_grid
    from repro.gofs.formats import partition_graph

    g_u, pg_u = get_pg("RN")
    g_w = road_grid(100, 100, drop_frac=0.03, seed=1, weighted=True)
    pg_w = partition_graph(g_w, bfs_grow_partition(g_w, NUM_PARTS, seed=0),
                           NUM_PARTS)
    mesh = compat.make_mesh((1,), ("parts",))

    records = {"dataset": "RN", "n": g_u.n, "num_parts": NUM_PARTS}

    delta_for = _delta_1pct
    gate_rows = []                   # (algo, best dense s, best megastep s)

    def bench(algo, g, pg0, semiring, init_fn):
        from repro.core import PhasedTierPlan
        # ---- version k: converge + teach the traffic profile ----
        hb = host_graph_block(pg0)
        prog_cold = SemiringProgram(semiring=semiring, init_fn=init_fn)
        prev_x = _teach_profile(pg0, hb, prog_cold, semiring)
        # ---- version k+1: the 1% insert batch (profile patched through) --
        res = delta_for(g, pg0, hb, weighted=(algo == "sssp"))
        pg1 = res.pg
        gb_dev = device_block(res.block)
        plan = TierPlan.from_block(res.block)
        plan_ph = PhasedTierPlan.for_resume(res.block)
        x0 = np.where(pg1.vmask, np.asarray(prev_x, np.float32),
                      np.inf if semiring == "min_plus" else -np.inf)
        frontier = res.dirty_insert & pg1.vmask
        extra = {"x0": x0, "frontier0": frontier}
        rec = {"insert_edges": int(res.stats["inserted"]) // 2,
               "mailbox_cap": pg1.mailbox_cap,
               "tiers": plan.counts(),
               "phases": plan_ph.counts(),
               "phase_boundaries": [int(b) for b in plan_ph.boundaries]}

        outs = {}
        engines = {}
        for mode in ("dense", "compact", "tiered", "auto", "phased"):
            prog = SemiringProgram(semiring=semiring, resume=True)
            eng = GopherEngine(pg1, prog, gb=gb_dev, exchange=mode,
                               tier_plan=(plan if mode == "tiered"
                                          else plan_ph if mode == "phased"
                                          else None))
            engines[mode] = eng
            (state, tele), dt = timed(eng.run, warmup=True, repeats=7,
                                      extra=extra)
            outs[mode] = np.asarray(state["x"])
            rec[mode] = dict(
                us_per_run=round(dt * 1e6),
                exchange=tele.exchange,
                supersteps=int(tele.supersteps),
                wire_slots=int(tele.wire_slots),
                bytes_on_wire=int(tele.bytes_on_wire),
                messages_sent=int(tele.messages_sent),
                wire_hist=[int(x) for x in tele.wire_hist],
                changed_hist=[int(x) for x in tele.changed_hist])
            if mode == "tiered":
                rec[mode]["spills"] = int(tele.spills)
                rec[mode]["retried"] = bool(tele.retried)
                assert not tele.retried, \
                    f"{algo}: tiered run spilled on the taught profile"
            if mode == "phased":
                rec[mode]["spills"] = int(tele.spills)
                rec[mode]["dense_retry_steps"] = int(tele.dense_retry_steps)
                rec[mode]["phase_hist"] = [int(x) for x in tele.phase_hist]
                rec[mode]["phase_switch_steps"] = \
                    [int(x) for x in tele.phase_switch_steps]
                rec[mode]["phase_wire_hist"] = \
                    [int(x) for x in tele.phase_wire]
            emit(f"comm_{algo}_inc_{mode}_RN", dt,
                 f"slots={tele.wire_slots};bytes={tele.bytes_on_wire}")
        for mode in ("compact", "tiered", "auto", "phased"):
            assert np.array_equal(outs["dense"], outs[mode]), \
                f"{algo}: {mode} exchange diverged from dense"
        # auto on local resolves to the Gopher Hot megastep route — the
        # fused one-launch-per-superstep loop, nothing on the wire
        assert rec["auto"]["exchange"] == "megastep"
        assert rec["auto"]["wire_slots"] == 0

        # THE SMALL-FRONTIER GATE, measured head-to-head: dense and the
        # fused route alternate run-for-run so scheduler drift on this
        # single-core CI box lands on both sides equally, and each side
        # keeps its best. At the 1-3 superstep warm floor both routes
        # compile to ONE executable whose wall clock is dominated by fixed
        # per-run cost, so per-algo the fused route must merely never LOSE
        # beyond the measured noise swing; the outright wins are asserted
        # where they are measurable — the aggregate across algos (run()
        # asserts sum(megastep) <= sum(dense) within the noise floor), the
        # cold gate below, and the 3-to-1 launch contraction in bench_obs.
        best_d = best_a = float("inf")
        for _ in range(10):
            _, dt = timed(engines["dense"].run, extra=extra)
            best_d = min(best_d, dt)
            _, dt = timed(engines["auto"].run, extra=extra)
            best_a = min(best_a, dt)
        rec["gate"] = {"dense_us": round(best_d * 1e6),
                       "megastep_us": round(best_a * 1e6)}
        gate_rows.append((algo, best_d, best_a))
        assert best_a <= 1.25 * best_d, \
            f"{algo}: megastep ({best_a * 1e6:.0f}us) lost to the dense " \
            f"path ({best_d * 1e6:.0f}us) beyond any plausible noise swing"

        if algo == "cc":
            # the COLD outright-win gate: full-frontier runs are ~100x
            # longer, so scheduler noise averages out and the fused route's
            # per-superstep savings must show up as a strict wall-clock win
            ecd = GopherEngine(pg1, prog_cold, gb=gb_dev, exchange="dense")
            eca = GopherEngine(pg1, prog_cold, gb=gb_dev, exchange="auto")
            ecd.run(), eca.run()
            cd = ca = float("inf")
            for _ in range(3):
                _, dt = timed(ecd.run)
                cd = min(cd, dt)
                _, dt = timed(eca.run)
                ca = min(ca, dt)
            rec["cold_gate"] = {"dense_us": round(cd * 1e6),
                                "megastep_us": round(ca * 1e6)}
            emit("comm_cc_cold_megastep_RN", ca,
                 f"dense={cd * 1e6:.0f}us")
            assert ca <= cd, \
                f"cc cold: megastep ({ca * 1e6:.0f}us) lost to the dense " \
                f"path ({cd * 1e6:.0f}us)"

        # ---- shard_map backend: tiered physical wire + parity (explicit —
        # auto resolves dense on this degenerate 1-device CI mesh) ----
        prog = SemiringProgram(semiring=semiring, resume=True)
        eng_sm = GopherEngine(pg1, prog, backend="shard_map", mesh=mesh,
                              exchange="tiered", tier_plan=plan)
        state_sm, tele_sm = eng_sm.run(extra=extra)
        assert tele_sm.exchange == "tiered"
        assert np.array_equal(np.asarray(state_sm["x"]), outs["dense"]), \
            f"{algo}: shard_map tiered diverged"
        assert not tele_sm.retried and tele_sm.spills == 0, \
            f"{algo}: shard_map tiered spilled"
        dense_round = NUM_PARTS * NUM_PARTS * pg1.mailbox_cap
        tiered_round = int(tele_sm.wire_hist[0]) if tele_sm.supersteps else 0
        # the Gopher Mesh acceptance gate: physical routed geometry <= 25%
        # of the dense P²·cap per round on the shard_map backend
        assert tiered_round <= 0.25 * dense_round, \
            f"{algo}: tiered geometry {tiered_round} > 25% of {dense_round}"
        rec["shard_map_wire_slots"] = int(tele_sm.wire_slots)
        rec["shard_map_round_slots"] = tiered_round
        rec["dense_round_slots"] = dense_round
        rec["physical_geometry_frac"] = round(tiered_round / dense_round, 4)

        rec["slot_reduction_modeled"] = round(
            rec["dense"]["wire_slots"] / max(rec["compact"]["wire_slots"], 1),
            1)
        rec["slot_reduction_physical"] = round(
            rec["dense"]["wire_slots"] / max(rec["tiered"]["wire_slots"], 1),
            1)
        rec["byte_reduction"] = round(
            rec["dense"]["bytes_on_wire"]
            / max(rec["compact"]["bytes_on_wire"], 1), 1)
        rec["bit_identical"] = True
        records[algo] = rec
        emit(f"comm_{algo}_reduction_RN", 0.0,
             f"modeled={rec['slot_reduction_modeled']}x;"
             f"physical={rec['slot_reduction_physical']}x;"
             f"geom={rec['physical_geometry_frac']}")

        # context: cold runs also benefit once the frontier contracts
        prog_cold = SemiringProgram(semiring=semiring, init_fn=init_fn)
        cold = {}
        for mode in ("dense", "compact", "tiered"):
            eng = GopherEngine(pg1, prog_cold, gb=gb_dev, exchange=mode,
                               tier_plan=(plan if mode == "tiered" else None))
            state, tele = eng.run()
            cold[mode] = dict(wire_slots=int(tele.wire_slots),
                              bytes_on_wire=int(tele.bytes_on_wire),
                              retried=bool(tele.retried))
        records[f"{algo}_cold"] = cold

    bench("cc", g_u, pg_u, "max_first", init_max_vertex)
    bench("bfs", g_u, pg_u, "min_plus",
          make_sssp_init(int(pg_u.part_of[0]), int(pg_u.local_of[0])))
    bench("sssp", g_w, pg_w, "min_plus",
          make_sssp_init(int(pg_w.part_of[0]), int(pg_w.local_of[0])))

    # the aggregate warm gate: across all three algos' head-to-head bests,
    # the fused route must hold the dense oracle to parity within the
    # single-core noise floor — that, the strict cold win, and the launch
    # contraction (bench_obs) are why auto prefers megastep on local
    agg_d = sum(d for _, d, _ in gate_rows)
    agg_a = sum(a for _, _, a in gate_rows)
    records["warm_gate"] = {"dense_us": round(agg_d * 1e6),
                            "megastep_us": round(agg_a * 1e6)}
    emit("comm_warm_gate_total", agg_a, f"dense={agg_d * 1e6:.0f}us")
    assert agg_a <= 1.08 * agg_d, \
        f"megastep warm aggregate ({agg_a * 1e6:.0f}us) lost to dense " \
        f"({agg_d * 1e6:.0f}us) beyond the noise floor"

    records["cold_phased"] = cold_phased_scenario()
    records["tier_churn"] = churn_scenario()
    if write_json:
        write_bench_json("comm", records)
    return records


def cold_phased_scenario():
    """The Gopher Phases acceptance gate: the RN 1%-insert incremental
    restart on a FRESH-REPLICA block whose per-pair profile was never
    taught (wire_ewma = the structural prior) — only the changed-histogram
    run shape is known. PR 4's static plan built from such a block is the
    structural worst-case geometry for EVERY round of the run; the phased
    plan rides the contraction inside the single run — the wide phase keeps
    the structural safety, the demotion trigger drops to the narrow bands
    as soon as the observed counts fit, and any narrow-phase overflow
    costs one dense-retried round, never correctness.

    Gated (CI runs this file on main): phased total routed slots <= 40% of
    the dense rounds·P²·cap AND strictly under the static cold plan, with
    results bit-identical to dense on both backends."""
    from benchmarks.common import NUM_PARTS, emit, get_pg
    from repro.core import (GopherEngine, PhasedTierPlan, SemiringProgram,
                            TierPlan, compat, device_block, host_graph_block,
                            init_max_vertex, make_sssp_init)

    g, pg0 = get_pg("RN")
    mesh = compat.make_mesh((1,), ("parts",))
    out = {}
    for algo, semiring, init_fn in (
            ("cc", "max_first", init_max_vertex),
            ("bfs", "min_plus", make_sssp_init(int(pg0.part_of[0]),
                                               int(pg0.local_of[0])))):
        hb = host_graph_block(pg0)
        prog_cold = SemiringProgram(semiring=semiring, init_fn=init_fn)
        prev = _teach_profile(pg0, hb, prog_cold, semiring, pairs=False)
        res = _delta_1pct(g, pg0, hb, weighted=False)
        pg1 = res.pg
        gb_dev = device_block(res.block)
        static = TierPlan.from_block(res.block)      # structural: the PR 4
                                                     # cold plan
        phased = PhasedTierPlan.for_resume(res.block)
        ident = np.inf if semiring == "min_plus" else -np.inf
        x0 = np.where(pg1.vmask, np.asarray(prev, np.float32), ident)
        extra = {"x0": x0, "frontier0": res.dirty_insert & pg1.vmask}
        P, cap = pg1.num_parts, pg1.mailbox_cap
        rec = {"phases": phased.counts(),
               "phase_boundaries": [int(b) for b in phased.boundaries]}
        runs = {}
        for mode, plan in (("dense", None), ("tiered", static),
                           ("phased", phased)):
            prog = SemiringProgram(semiring=semiring, resume=True)
            eng = GopherEngine(pg1, prog, gb=gb_dev, exchange=mode,
                               tier_plan=plan)
            state, tele = eng.run(extra=extra)
            runs[mode] = np.asarray(state["x"])
            dense_total = (tele.supersteps + 1) * P * P * cap
            rec[mode] = dict(
                supersteps=int(tele.supersteps),
                wire_slots=int(tele.wire_slots),
                bytes_on_wire=int(tele.bytes_on_wire),
                geometry_frac=round(tele.wire_slots / dense_total, 4))
            if mode == "phased":
                rec[mode]["spills"] = int(tele.spills)
                rec[mode]["dense_retry_steps"] = int(tele.dense_retry_steps)
                rec[mode]["phase_hist"] = [int(x) for x in tele.phase_hist]
                rec[mode]["phase_switch_steps"] = \
                    [int(x) for x in tele.phase_switch_steps]
                rec[mode]["phase_wire_hist"] = \
                    [int(x) for x in tele.phase_wire]
                rec[mode]["wire_hist"] = [int(x) for x in tele.wire_hist]
        for mode in ("tiered", "phased"):
            assert np.array_equal(runs["dense"], runs[mode]), \
                f"cold {algo}: {mode} diverged from dense"
        # shard_map parity for the phased cold plan
        prog = SemiringProgram(semiring=semiring, resume=True)
        st_sm, tt_sm = GopherEngine(pg1, prog, backend="shard_map",
                                    mesh=mesh, exchange="phased",
                                    tier_plan=phased).run(extra=extra)
        assert np.array_equal(runs["dense"], np.asarray(st_sm["x"])), \
            f"cold {algo}: shard_map phased diverged"
        # THE GATE: a cold phased run lands in the 25-40%-of-dense band the
        # static plan only reaches with a taught (warm) profile
        frac = rec["phased"]["geometry_frac"]
        assert frac <= 0.40, \
            f"cold {algo}: phased geometry {frac} > 40% of dense"
        assert rec["phased"]["wire_slots"] < rec["tiered"]["wire_slots"], \
            f"cold {algo}: phased did not beat the static cold plan"
        rec["static_frac"] = rec["tiered"]["geometry_frac"]
        out[algo] = rec
        emit(f"comm_{algo}_cold_phased_RN", 0.0,
             f"frac={frac};static={rec['static_frac']};"
             f"switches={rec['phased']['phase_switch_steps']}")
    return out


def churn_scenario(versions: int = 10):
    """Tier churn: a delta stream whose hotspot MIGRATES across partition
    pairs — the worst case for a history-based profile. Each version
    reopens a batch of edges inside a sliding window of the grid, so the
    pairs that were hot last version go quiet and fresh pairs wake up.
    Records per version: spills, escalations, physical geometry vs dense,
    and whether the dense fallback had to repair the run. Two plans run per
    version: the FRESH plan (rebuilt from the patched block, whose
    announce_frontier floor pre-warms every reachable pair) and the STALE
    plan carried from the previous version (a replica that hasn't replayed
    the delta's profile events) — the stale runs are where overflow,
    escalation and the dense retry earn their keep."""
    from benchmarks.common import NUM_PARTS, emit
    from repro.core import (GopherEngine, SemiringProgram, TierPlan,
                            device_block, host_graph_block, init_max_vertex,
                            update_profile)
    from repro.gofs import EdgeDelta, apply_delta, bfs_grow_partition, \
        road_grid
    from repro.gofs.formats import partition_graph

    rows = cols = 60
    g = road_grid(rows, cols, drop_frac=0.25, seed=5, weighted=False)
    pg = partition_graph(g, bfs_grow_partition(g, NUM_PARTS, seed=0),
                         NUM_PARTS)
    hb = host_graph_block(pg)
    prog_cold = SemiringProgram(semiring="max_first", init_fn=init_max_vertex)
    prev = _teach_profile(pg, hb, prog_cold, "max_first")
    rng = np.random.default_rng(17)

    def window_delta(v):
        # hotspot band slides across the grid with the version number
        band = (v * rows // versions, (v + 2) * rows // versions)
        vs = np.arange(g.n).reshape(rows, cols)[band[0]:band[1]].reshape(-1)
        iu = rng.choice(vs, 40)
        off = rng.choice([-1, 1, -cols, cols], 40)
        iv = np.clip(iu + off, 0, g.n - 1)
        keep = iu != iv
        return EdgeDelta.inserts(iu[keep], iv[keep])

    out = {"versions": versions, "per_version": [],
           "escalations_total": 0, "spill_versions": 0,
           "stale_escalations_total": 0, "stale_spill_versions": 0}
    stale_plan = TierPlan.from_block(hb)
    for v in range(versions):
        res = apply_delta(pg, window_delta(v), directed=False, block=hb)
        pg, hb = res.pg, hb if res.block is None else res.block
        plan = TierPlan.from_block(hb)
        gbd = device_block(hb)
        x0 = np.where(pg.vmask, np.asarray(prev, np.float32), -np.inf)
        extra = {"x0": x0, "frontier0": res.dirty_insert & pg.vmask}
        prog = SemiringProgram(semiring="max_first", resume=True)
        sd, _ = GopherEngine(pg, prog, gb=gbd, exchange="dense").run(
            extra=extra)
        # stale replica: last version's plan against this version's frontier
        stale = dict(skipped=True)
        if stale_plan.cap == pg.mailbox_cap:
            eng_s = GopherEngine(pg, prog, gb=gbd, exchange="tiered",
                                 tier_plan=stale_plan)
            st_s, tele_s = eng_s.run(extra=extra)
            assert np.array_equal(np.asarray(sd["x"]), np.asarray(st_s["x"])), \
                f"churn v{v}: stale tiered diverged"
            stale = dict(spills=int(tele_s.spills),
                         escalations=int(tele_s.escalations),
                         retried=bool(tele_s.retried))
            out["stale_escalations_total"] += int(tele_s.escalations)
            out["stale_spill_versions"] += int(tele_s.retried)
        # fresh plan: rebuilt from the patched block (announced frontier)
        eng = GopherEngine(pg, prog, gb=gbd, exchange="tiered",
                           tier_plan=plan)
        state, tele = eng.run(extra=extra)
        assert np.array_equal(np.asarray(sd["x"]), np.asarray(state["x"])), \
            f"churn v{v}: tiered diverged"
        update_profile(hb, tele.pair_slots, tele.pair_rounds)
        prev = np.asarray(state["x"])
        stale_plan = plan
        rounds = tele.supersteps + 1
        dense_bytes = (rounds * NUM_PARTS * NUM_PARTS
                       * pg.mailbox_cap * 4)
        out["per_version"].append(dict(
            version=pg.version,
            tiers=plan.counts(),
            spills=int(tele.spills),
            escalations=int(tele.escalations),
            retried=bool(tele.retried),
            stale=stale,
            round_slots=(int(tele.wire_hist[0]) if tele.supersteps else 0),
            bytes_on_wire=int(tele.bytes_on_wire),
            bytes_vs_dense=round(tele.bytes_on_wire / dense_bytes, 4)))
        out["escalations_total"] += int(tele.escalations)
        out["spill_versions"] += int(tele.retried)
    frac = [r["bytes_vs_dense"] for r in out["per_version"]]
    out["bytes_vs_dense_mean"] = round(float(np.mean(frac)), 4)
    emit("comm_tier_churn", 0.0,
         f"escalations={out['escalations_total']};"
         f"spill_versions={out['spill_versions']};"
         f"stale_escalations={out['stale_escalations_total']};"
         f"bytes_vs_dense={out['bytes_vs_dense_mean']}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
