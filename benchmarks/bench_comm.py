"""Gopher Wire: communication volume of the superstep exchange.

Scenario (the RN-analogue incremental workload): a converged CC/BFS/SSSP
fixpoint on the road network at version k, a 1% edge-insert batch arrives,
and the frontier-seeded incremental restart re-converges on version k+1.
The dense mailbox ships every partition pair's full cap-slot row every
superstep regardless of how little changed; the frontier-compacted exchange
ships each pair's packed active prefix plus a count header, so its payload
tracks the (tiny) dirty frontier.

Recorded per (algo, exchange mode): total exchanged slots, modeled
bytes-on-wire, per-superstep wire/changed histograms, and wall time — with
the results asserted BIT-IDENTICAL between modes on both backends. Also a
cold-run row per algo for context (the compact exchange pays for itself
there too once the frontier contracts). Writes BENCH_comm.json.
"""
from __future__ import annotations

import numpy as np


def run(write_json: bool = True):
    from benchmarks.common import NUM_PARTS, emit, get_pg, timed, \
        write_bench_json
    from repro.algorithms import bfs, connected_components, sssp
    from repro.core import (GopherEngine, SemiringProgram, compat,
                            device_block, host_graph_block, init_max_vertex,
                            make_sssp_init)
    from repro.gofs import EdgeDelta, apply_delta, bfs_grow_partition, \
        road_grid
    from repro.gofs.formats import partition_graph

    g_u, pg_u = get_pg("RN")
    g_w = road_grid(100, 100, drop_frac=0.03, seed=1, weighted=True)
    pg_w = partition_graph(g_w, bfs_grow_partition(g_w, NUM_PARTS, seed=0),
                           NUM_PARTS)
    mesh = compat.make_mesh((1,), ("parts",))

    records = {"dataset": "RN", "n": g_u.n, "num_parts": NUM_PARTS}

    def delta_for(g, pg0, weighted, seed=7):
        from benchmarks.bench_incremental import _reopened_edges
        num_ins = max(1, (g.nnz // 2) // 100)          # the 1% batch
        iu, iv = _reopened_edges(g, 100, 100, num_ins, seed=seed)
        iw = (np.random.default_rng(8).uniform(5.0, 10.0, iu.size)
              .astype(np.float32) if weighted else None)
        return apply_delta(pg0, EdgeDelta.inserts(iu, iv, iw),
                           directed=False, block=host_graph_block(pg0))

    def bench(algo, g, pg0, semiring, init_fn, prev_x):
        res = delta_for(g, pg0, weighted=(algo == "sssp"))
        pg1 = res.pg
        gb_dev = device_block(res.block)
        x0 = np.where(pg1.vmask, np.asarray(prev_x, np.float32),
                      np.inf if semiring == "min_plus" else -np.inf)
        frontier = res.dirty_insert & pg1.vmask
        extra = {"x0": x0, "frontier0": frontier}
        rec = {"insert_edges": int(res.stats["inserted"]) // 2,
               "mailbox_cap": pg1.mailbox_cap}

        outs = {}
        for mode in ("dense", "compact"):
            prog = SemiringProgram(semiring=semiring, resume=True)
            eng = GopherEngine(pg1, prog, gb=gb_dev, exchange=mode)
            (state, tele), dt = timed(eng.run, warmup=True, repeats=3,
                                      extra=extra)
            outs[mode] = np.asarray(state["x"])
            rec[mode] = dict(
                us_per_run=round(dt * 1e6),
                supersteps=int(tele.supersteps),
                wire_slots=int(tele.wire_slots),
                bytes_on_wire=int(tele.bytes_on_wire),
                messages_sent=int(tele.messages_sent),
                wire_hist=[int(x) for x in tele.wire_hist],
                changed_hist=[int(x) for x in tele.changed_hist])
            emit(f"comm_{algo}_inc_{mode}_RN", dt,
                 f"slots={tele.wire_slots};bytes={tele.bytes_on_wire}")
        assert np.array_equal(outs["dense"], outs["compact"]), \
            f"{algo}: compact exchange diverged from dense"
        # shard_map backend: same wire accounting, same bits
        prog = SemiringProgram(semiring=semiring, resume=True)
        eng_sm = GopherEngine(pg1, prog, backend="shard_map", mesh=mesh,
                              exchange="compact")
        state_sm, tele_sm = eng_sm.run(extra=extra)
        assert np.array_equal(np.asarray(state_sm["x"]), outs["compact"]), \
            f"{algo}: shard_map compact diverged"
        rec["shard_map_wire_slots"] = int(tele_sm.wire_slots)
        rec["slot_reduction"] = round(
            rec["dense"]["wire_slots"] / max(rec["compact"]["wire_slots"], 1),
            1)
        rec["byte_reduction"] = round(
            rec["dense"]["bytes_on_wire"]
            / max(rec["compact"]["bytes_on_wire"], 1), 1)
        rec["bit_identical"] = True
        records[algo] = rec
        emit(f"comm_{algo}_reduction_RN", 0.0,
             f"slots={rec['slot_reduction']}x;bytes={rec['byte_reduction']}x")

        # context: cold runs also benefit once the frontier contracts
        prog_cold = SemiringProgram(semiring=semiring, init_fn=init_fn)
        cold = {}
        for mode in ("dense", "compact"):
            eng = GopherEngine(pg1, prog_cold, gb=gb_dev, exchange=mode)
            state, tele = eng.run()
            cold[mode] = dict(wire_slots=int(tele.wire_slots),
                              bytes_on_wire=int(tele.bytes_on_wire))
        records[f"{algo}_cold"] = cold

    prev_cc = connected_components(pg_u)[0]        # (P, v_max) labels
    bench("cc", g_u, pg_u, "max_first", init_max_vertex, prev_cc)

    prev_bfs, _ = bfs(pg_u, 0)
    bench("bfs", g_u, pg_u, "min_plus",
          make_sssp_init(int(pg_u.part_of[0]), int(pg_u.local_of[0])),
          prev_bfs)

    prev_sssp, _ = sssp(pg_w, 0)
    bench("sssp", g_w, pg_w, "min_plus",
          make_sssp_init(int(pg_w.part_of[0]), int(pg_w.local_of[0])),
          prev_sssp)

    if write_json:
        write_bench_json("comm", records)
    return records


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
