"""Paper Fig 4(c) focus: superstep counts vs diameter, and the paper's
R²≈1 correlation between compute-improvement and vertex diameter (§6.3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_pg, timed
from repro.algorithms import connected_components
from repro.core import meta_diameter, vertex_diameter


def run():
    rows = []
    for ds in ("RN", "TR", "LJ"):
        g, pg = get_pg(ds)
        dv = vertex_diameter(g, sample=32)
        dm = meta_diameter(pg, sample=32)
        (_, _, t_sub), dt_s = timed(lambda: connected_components(pg, mode="subgraph"))
        (_, _, t_vert), dt_v = timed(lambda: connected_components(pg, mode="vertex"))
        emit(f"fig4c_supersteps_{ds}", dt_s,
             f"sub={t_sub.supersteps};vert={t_vert.supersteps};"
             f"d_vertex={dv};d_meta={dm}")
        rows.append((ds, dv, dm, t_sub.supersteps, t_vert.supersteps,
                     dt_s, dt_v))
    # correlation of compute improvement with vertex diameter (paper §6.3)
    dvs = np.array([r[1] for r in rows], float)
    imp = np.array([r[6] / max(r[5], 1e-9) for r in rows], float)
    if len(rows) >= 3 and np.std(dvs) > 0 and np.std(imp) > 0:
        r2 = float(np.corrcoef(dvs, imp)[0, 1] ** 2)
        emit("fig4c_r2_diameter_vs_improvement", 0.0, f"r2={r2:.3f}")
    return rows


if __name__ == "__main__":
    run()
