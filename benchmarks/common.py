"""Shared benchmark scaffolding: the paper's three dataset analogues at
CPU-benchmark scale, timing helpers, CSV emission + BENCH_*.json recording."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.gofs import (bfs_grow_partition, hash_partition, powerlaw_social,
                        road_grid, subgraph_balanced_partition, trace_star)
from repro.gofs.formats import partition_graph

# Scaled-down analogues of Table 1 (same shape statistics, CPU-feasible sizes)
DATASETS = {
    "RN": lambda: road_grid(100, 100, drop_frac=0.03, seed=1),   # 10k vertices, high diameter, many WCC
    "TR": lambda: trace_star(20_000, n_hubs=8, seed=2),          # powerlaw, one WCC, mega-hub
    "LJ": lambda: powerlaw_social(20_000, m=5, seed=3),          # dense powerlaw, small diameter
}
PARTITIONERS = {
    "hash": hash_partition,
    "bfs": bfs_grow_partition,
    "balanced": subgraph_balanced_partition,
}
NUM_PARTS = 8  # "machines" (virtual partitions on the local backend)


def timed(fn, *args, repeats: int = 1, warmup: bool = False, **kw):
    """min-of-N wall clock; warmup=True runs once untimed first (exclude jit
    compilation — the paper's makespan has no compile phase)."""
    if warmup:
        fn(*args, **kw)
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


RESULTS = []        # every emit() lands here so drivers can write BENCH json


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}")
    RESULTS.append(dict(name=name, us_per_call=round(seconds * 1e6),
                        derived=derived))


def write_bench_json(suite: str, payload=None) -> str:
    """Write BENCH_<suite>.json at the repo root (the perf-trajectory record
    the roadmap tracks). ``payload`` defaults to the rows emit() collected
    since process start. A Gopher Scope metrics snapshot of everything the
    run fed the default registry (engine counters, tier-plan builds, profile
    drift, serving latencies) rides along as BENCH_<suite>_metrics.json."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(payload if payload is not None else RESULTS, f, indent=1)
    from repro.obs import metrics as obs_metrics
    obs_metrics.default_registry().write_json(
        os.path.join(root, f"BENCH_{suite}_metrics.json"))
    return path


_pg_cache = {}


def get_pg(ds: str, partitioner: str = "bfs"):
    key = (ds, partitioner)
    if key not in _pg_cache:
        g = DATASETS[ds]()
        assign = PARTITIONERS[partitioner](g, NUM_PARTS, seed=0)
        _pg_cache[key] = (g, partition_graph(g, assign, NUM_PARTS))
    return _pg_cache[key]
