"""Paper Fig 5: per-partition compute-time distribution (straggler analysis)
for PageRank-like sweeps, plus the paper's §7 proposed fix (sub-graph-balanced
partitioning) and the beyond-paper bounded-local-iters mitigation.

On the SPMD engine the straggler signal is the per-partition cumulative
local-sweep iteration count (tele.local_iters) and the sub-graph size skew."""
from __future__ import annotations

import numpy as np

from benchmarks.common import NUM_PARTS, emit, get_pg, timed
from repro.algorithms import connected_components
from repro.core.subgraph import subgraph_sizes


def run():
    rows = []
    for ds in ("TR", "LJ"):
        for part in ("hash", "bfs", "balanced"):
            g, pg = get_pg(ds, part)
            sizes = subgraph_sizes(pg)
            biggest = np.array([s.max() if len(s) else 0 for s in sizes])
            (labels, ncc, tele), dt = timed(
                lambda: connected_components(pg, mode="subgraph"))
            li = tele.local_iters.astype(float)
            skew = float(li.max() / max(li.mean(), 1e-9))
            emit(f"fig5_straggler_{ds}_{part}", dt,
                 f"iter_skew={skew:.2f};max_sg={int(biggest.max())};"
                 f"supersteps={tele.supersteps}")
            rows.append((ds, part, skew, int(biggest.max())))
    # the balanced partitioner must not make the biggest sub-graph worse
    by = {(d, p): (s, b) for d, p, s, b in rows}
    for ds in ("TR", "LJ"):
        assert by[(ds, "balanced")][1] <= max(by[(ds, "hash")][1],
                                              by[(ds, "bfs")][1])
    return rows


if __name__ == "__main__":
    run()
