"""Paper Fig 5: per-partition compute-time distribution (straggler analysis)
for PageRank-like sweeps, plus the paper's §7 proposed fix (sub-graph-balanced
partitioning) and the beyond-paper bounded-local-iters mitigation.

On the SPMD engine the straggler signal is the per-partition cumulative
local-sweep iteration count (tele.local_iters) and the sub-graph size skew;
the scoring now lives in repro.obs.skew (Gopher Scope), so this bench, the
engine metrics and the serving stats all rank stragglers with the SAME
imbalance score."""
from __future__ import annotations

import numpy as np

from benchmarks.common import NUM_PARTS, emit, get_pg, timed
from repro.algorithms import connected_components
from repro.core.subgraph import subgraph_sizes
from repro.gofs.formats import partition_graph
from repro.obs.skew import imbalance_score, skew_report


def run():
    rows = []
    for ds in ("TR", "LJ"):
        for part in ("hash", "bfs", "balanced"):
            g, pg = get_pg(ds, part)
            sizes = subgraph_sizes(pg)
            biggest = np.array([s.max() if len(s) else 0 for s in sizes])
            (labels, ncc, tele), dt = timed(
                lambda: connected_components(pg, mode="subgraph"))
            rep = skew_report(tele)
            skew = rep["imbalance"]
            emit(f"fig5_straggler_{ds}_{part}", dt,
                 f"iter_skew={skew:.2f};cv={rep['cv']:.2f};"
                 f"straggler=p{rep['straggler']};"
                 f"max_sg={int(biggest.max())};supersteps={tele.supersteps}")
            rows.append((ds, part, skew, int(biggest.max())))
    # the balanced partitioner must not make the biggest sub-graph worse
    by = {(d, p): (s, b) for d, p, s, b in rows}
    for ds in ("TR", "LJ"):
        assert by[(ds, "balanced")][1] <= max(by[(ds, "hash")][1],
                                              by[(ds, "bfs")][1])
    # Gopher Scope gate: the shared imbalance score must RANK a degenerate
    # one-giant-partition split above the balanced partitioner on the same
    # graph + algorithm — the ordering Gopher Balance's migration policy
    # will trust
    g, pg_bal = get_pg("RN", "balanced")
    assign = np.zeros(g.n, np.int64)
    assign[:NUM_PARTS - 1] = np.arange(1, NUM_PARTS)   # 7 singletons + 1 giant
    pg_skew = partition_graph(g, assign, NUM_PARTS)
    (_, _, tele_s), _ = timed(
        lambda: connected_components(pg_skew, mode="subgraph"))
    (_, _, tele_b), _ = timed(
        lambda: connected_components(pg_bal, mode="subgraph"))
    s_skew = imbalance_score(tele_s.local_iters)
    s_bal = imbalance_score(tele_b.local_iters)
    emit("fig5_imbalance_rank_RN", 0.0,
         f"skewed={s_skew:.2f};balanced={s_bal:.2f}")
    assert s_skew > s_bal, \
        f"imbalance score failed to rank skewed ({s_skew}) above " \
        f"balanced ({s_bal})"
    return rows


if __name__ == "__main__":
    run()
