"""Gopher Scope smoke gates (CI runs this file on main).

Four acceptance checks on tiny CC + SSSP workloads:

  1. TRACED runs produce a schema-valid Chrome trace (nested run -> phase ->
     superstep -> stage spans, balanced) and a schema-valid metrics
     snapshot — and their results are BIT-IDENTICAL to the untraced
     compiled-loop runs. (Pinned to the staged ``compact`` route: the
     fused megastep route collapses the per-stage spans by design and
     has its own gate below.)
  2. DISABLED tracing is free: min-of-N wall clock of a run holding a
     disabled Tracer stays within 2% of the plain run (same compiled
     loop via the shared runner cache — the only delta is the
     ``tracer.enabled`` check, so anything past noise is a regression).
  3. LAUNCH CONTRACTION: the Gopher Hot megastep route dispatches ONE
     fused kernel per superstep where the staged route dispatches >= 3
     (sweep, pack, exchange-apply) — asserted via the tracer's
     ``dispatches`` count, with bit-identical results across the two
     traced routes.
  4. The artifacts land: BENCH_obs.json rows + the BENCH_obs_metrics.json
     registry snapshot write_bench_json emits for every suite.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import (GopherEngine, SemiringProgram, init_max_vertex,
                        make_sssp_init)
from repro.gofs import bfs_grow_partition, road_grid
from repro.gofs.formats import partition_graph
from repro.obs import (Tracer, metrics, validate_chrome_trace,
                       validate_metrics)

OVERHEAD_FRAC = 0.02     # disabled tracing must cost < 2%
TIMED_REPEATS = 20       # min-of-N absorbs scheduler noise


def _programs(pg):
    return {
        "cc": SemiringProgram(semiring="max_first", init_fn=init_max_vertex),
        "sssp": SemiringProgram(
            semiring="min_plus",
            init_fn=make_sssp_init(int(pg.part_of[0]), int(pg.local_of[0]))),
    }


def run():
    g = road_grid(24, 24, seed=1)
    pg = partition_graph(g, bfs_grow_partition(g, 4, seed=0), 4)

    for algo, prog in _programs(pg).items():
        # -------- gate 1: traced run, valid artifacts, identical results --
        plain = GopherEngine(pg, prog, exchange="compact")
        state_p, tele_p = plain.run()
        tracer = Tracer(enabled=True)
        traced = GopherEngine(pg, prog, exchange="compact", tracer=tracer)
        state_t, tele_t = traced.run()
        np.testing.assert_array_equal(np.asarray(state_p["x"]),
                                      np.asarray(state_t["x"]))
        assert tele_t.supersteps == tele_p.supersteps
        assert tele_t.wire_slots == tele_p.wire_slots
        assert tracer.balanced, f"open spans: {tracer.open_spans()}"
        trace = tracer.chrome_trace()
        validate_chrome_trace(trace)
        names = {ev["name"] for ev in trace["traceEvents"]}
        assert {"run", "phase", "superstep", "sweep", "pack", "exchange",
                "halt-vote"} <= names, f"missing stage spans: {names}"
        validate_metrics(metrics.default_registry().snapshot())
        emit(f"obs_traced_{algo}", 0.0,
             f"spans={len(trace['traceEvents'])};"
             f"supersteps={tele_t.supersteps}")

        # -------- gate 2: disabled tracing is free ------------------------
        off = GopherEngine(pg, prog, exchange="compact",
                           tracer=Tracer(enabled=False))
        _, t_plain = timed(plain.run, repeats=TIMED_REPEATS, warmup=True)
        _, t_off = timed(off.run, repeats=TIMED_REPEATS, warmup=True)
        overhead = t_off / t_plain - 1.0
        emit(f"obs_disabled_overhead_{algo}", t_off,
             f"plain_us={t_plain * 1e6:.0f};overhead={overhead * 100:.2f}%")
        assert overhead < OVERHEAD_FRAC, \
            f"disabled tracing costs {overhead * 100:.2f}% (> " \
            f"{OVERHEAD_FRAC * 100:.0f}%) on {algo}"

        # -------- gate 3: megastep launch contraction, 3+/superstep -> 1 --
        d_staged = tracer.counts.get("dispatches", 0)
        s = tele_t.supersteps
        assert d_staged >= 3 * s + 3, \
            f"staged route dispatched {d_staged} (< {3 * s + 3}) on {algo}"
        tr_m = Tracer(enabled=True)
        mega = GopherEngine(pg, prog, exchange="megastep", tracer=tr_m)
        state_m, tele_m = mega.run()
        np.testing.assert_array_equal(np.asarray(state_m["x"]),
                                      np.asarray(state_t["x"]))
        assert tele_m.supersteps == s
        d_mega = tr_m.counts.get("dispatches", 0)
        # prologue pack + fused superstep kernels + final unpack
        assert d_mega == s + 2, \
            f"megastep dispatched {d_mega}, expected {s + 2} on {algo}"
        emit(f"obs_launch_contraction_{algo}", 0.0,
             f"staged={d_staged};megastep={d_mega};supersteps={s}")


if __name__ == "__main__":
    from benchmarks.common import write_bench_json
    run()
    import sys
    print(f"# wrote {write_bench_json('obs')}", file=sys.stderr)
