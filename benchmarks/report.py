"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON, plus
the Gopher Scope artifacts BENCH runs emit: BENCH_*_metrics.json registry
snapshots (metrics_table) and scope_trace.json Chrome traces (trace_table).

    python benchmarks/report.py BENCH_comm_metrics.json   # metrics table
    python benchmarks/report.py scope_trace.json          # span summary
    python benchmarks/report.py dryrun_final.json         # legacy tables
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def metrics_table(path: str) -> str:
    """Markdown table of a gopher-metrics-v1 snapshot."""
    snap = json.load(open(path))
    assert snap.get("format") == "gopher-metrics-v1", \
        f"{path} is not a metrics snapshot"
    out = ["| metric | kind | value |", "|---|---|---:|"]
    for k, v in snap.get("counters", {}).items():
        out.append(f"| `{k}` | counter | {v:g} |")
    for k, v in snap.get("gauges", {}).items():
        out.append(f"| `{k}` | gauge | {v:g} |")
    for k, h in snap.get("histograms", {}).items():
        out.append(f"| `{k}` | histogram | n={h['count']} mean={h['mean']:.4g}"
                   f" p50={h['p50']:.4g} p95={h['p95']:.4g}"
                   f" p99={h['p99']:.4g} |")
    return "\n".join(out)


def trace_table(path: str) -> str:
    """Per-span-name rollup of a Gopher Scope Chrome trace: count, total and
    mean wall-clock — the aggregate view of the Perfetto file."""
    obj = json.load(open(path))
    agg = defaultdict(lambda: [0, 0.0])
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") == "X":
            agg[ev["name"]][0] += 1
            agg[ev["name"]][1] += float(ev["dur"])
    out = ["| span | count | total (ms) | mean (ms) |", "|---|---:|---:|---:|"]
    for name, (n, tot_us) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        out.append(f"| {name} | {n} | {tot_us / 1e3:.3f} "
                   f"| {tot_us / 1e3 / n:.3f} |")
    return "\n".join(out)


def roofline_table(path: str, mesh: str) -> str:
    rs = [r for r in json.load(open(path))
          if r.get("mesh") == mesh and not r.get("skipped") and "error" not in r]
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck "
           "| MODEL/HLO flops | roofline frac | args GiB/dev | temp GiB/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|---:|"]
    for r in sorted(rs, key=lambda x: (x["arch"], x["shape"])):
        m = r.get("mem_stats") or {}
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} "
            f"| {r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {m.get('argument', 0)/2**30:.2f} | {m.get('temp', 0)/2**30:.2f} |")
    return "\n".join(out)


def dryrun_table(path: str) -> str:
    rs = json.load(open(path))
    out = ["| arch | shape | 16x16 | 2x16x16 | collective mix (16x16, GB/dev) |",
           "|---|---|---|---|---|"]
    cells = {}
    for r in rs:
        key = (r["arch"], r["shape"])
        cells.setdefault(key, {})[r.get("mesh", "16x16")] = r
    for (a, s), by in sorted(cells.items()):
        row = []
        for mesh in ("16x16", "2x16x16"):
            r = by.get(mesh)
            if r is None:
                row.append("—")
            elif r.get("skipped"):
                row.append("skip")
            elif "error" in r:
                row.append("FAIL")
            else:
                row.append(f"OK ({r['compile_seconds']:.0f}s)")
        r = by.get("16x16", {})
        mix = ""
        cd = r.get("coll_detail", {}).get("bytes", {})
        if cd:
            mix = " ".join(f"{k.split('-')[-1]}={v/1e9:.1f}"
                           for k, v in cd.items() if v > 1e8)
        out.append(f"| {a} | {s} | {row[0]} | {row[1]} | {mix} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_final.json"
    head = json.load(open(path))
    if isinstance(head, dict) and head.get("format") == "gopher-metrics-v1":
        print(f"## Metrics — {path}\n")
        print(metrics_table(path))
    elif isinstance(head, dict) and "traceEvents" in head:
        print(f"## Trace spans — {path}\n")
        print(trace_table(path))
    else:
        print("## Dry-run matrix\n")
        print(dryrun_table(path))
        print("\n## Roofline (single-pod 16x16)\n")
        print(roofline_table(path, "16x16"))
        print("\n## Roofline (multi-pod 2x16x16)\n")
        print(roofline_table(path, "2x16x16"))
