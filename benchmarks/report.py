"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSON."""
from __future__ import annotations

import json
import sys


def roofline_table(path: str, mesh: str) -> str:
    rs = [r for r in json.load(open(path))
          if r.get("mesh") == mesh and not r.get("skipped") and "error" not in r]
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck "
           "| MODEL/HLO flops | roofline frac | args GiB/dev | temp GiB/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|---:|---:|"]
    for r in sorted(rs, key=lambda x: (x["arch"], x["shape"])):
        m = r.get("mem_stats") or {}
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} "
            f"| {r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {m.get('argument', 0)/2**30:.2f} | {m.get('temp', 0)/2**30:.2f} |")
    return "\n".join(out)


def dryrun_table(path: str) -> str:
    rs = json.load(open(path))
    out = ["| arch | shape | 16x16 | 2x16x16 | collective mix (16x16, GB/dev) |",
           "|---|---|---|---|---|"]
    cells = {}
    for r in rs:
        key = (r["arch"], r["shape"])
        cells.setdefault(key, {})[r.get("mesh", "16x16")] = r
    for (a, s), by in sorted(cells.items()):
        row = []
        for mesh in ("16x16", "2x16x16"):
            r = by.get(mesh)
            if r is None:
                row.append("—")
            elif r.get("skipped"):
                row.append("skip")
            elif "error" in r:
                row.append("FAIL")
            else:
                row.append(f"OK ({r['compile_seconds']:.0f}s)")
        r = by.get("16x16", {})
        mix = ""
        cd = r.get("coll_detail", {}).get("bytes", {})
        if cd:
            mix = " ".join(f"{k.split('-')[-1]}={v/1e9:.1f}"
                           for k, v in cd.items() if v > 1e8)
        out.append(f"| {a} | {s} | {row[0]} | {row[1]} | {mix} |")
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_final.json"
    print("## Dry-run matrix\n")
    print(dryrun_table(path))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(path, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(path, "2x16x16"))
