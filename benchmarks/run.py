"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
    fig4a_*   makespan, Gopher vs vertex-centric (paper Fig 4a)
    fig4b_*   load time, GoFS vs monolithic (paper Fig 4b)
    fig4c_*   superstep counts + diameter correlation (paper Fig 4c, §6.3)
    fig5_*    straggler/skew distribution + partitioner fix (paper Fig 5, §7)
    blockrank_* BlockRank vs classic PageRank supersteps (paper §5.3)
    serving_* batched multi-query serving QPS vs sequential (Gopher Serve)
    incremental_* delta restart vs full recompute (Gopher Delta)
    comm_*    exchange volume, compact vs dense mailbox (Gopher Wire)
    obs_*     tracing artifacts valid + disabled-tracing overhead (Gopher
              Scope)

Every emitted row is also recorded to BENCH_paper_suite.json at the repo
root (plus BENCH_incremental.json / BENCH_comm.json from the incremental
and comm benches) so the perf trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import sys


def _blockrank():
    from benchmarks.common import emit, get_pg, timed
    from repro.algorithms import blockrank, pagerank
    g, pg = get_pg("RN")
    (r1, t1), dt1 = timed(lambda: pagerank(pg, num_iters=60, tol=1e-7))
    (r2, t2, info), dt2 = timed(lambda: blockrank(pg, tol=1e-7, max_iters=60))
    emit("blockrank_classic_RN", dt1, f"supersteps={t1.supersteps}")
    emit("blockrank_seeded_RN", dt2,
         f"supersteps={t2.supersteps};blocks={info['num_meta']}")


def main() -> None:
    from benchmarks import (bench_comm, bench_goffish_vs_vertex,
                            bench_incremental, bench_loading, bench_obs,
                            bench_serving, bench_straggler, bench_supersteps)
    from benchmarks.common import write_bench_json
    print("name,us_per_call,derived")
    bench_goffish_vs_vertex.run()
    bench_loading.run()
    bench_supersteps.run()
    bench_straggler.run()
    _blockrank()
    bench_serving.run()
    bench_incremental.run()
    bench_comm.run()
    bench_obs.run()
    print(f"# wrote {write_bench_json('paper_suite')}", file=sys.stderr)


if __name__ == "__main__":
    main()
