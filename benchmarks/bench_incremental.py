"""Incremental analytics vs full recompute (the temporal-GoFS payoff).

Scenario: a converged CC/BFS/SSSP fixpoint on a road network at version k,
then a 1% edge-insert batch arrives — previously-closed road segments reopen
(grid edges absent from the build), the realistic temporal update for the
RN dataset. Compare

    full        steady-state cold engine run on the already-built version-
                k+1 graph (engine + compiled loop REUSED across calls, graph
                build and compile excluded — conservative in full's favor)
    incremental apply_delta (INCLUDED — it's part of the ingest path) with
                ZERO-REPACK block patching (the version-k host block is
                patched in O(|delta|) instead of re-packed) + frontier-
                seeded resume from the version-k fixpoint over the
                frontier-compacted sparse exchange

and assert the answers are bit-identical. Also times the per-version fixed
cost both ways — old ingest (apply_delta + cold host_graph_block re-pack)
vs zero-repack ingest (apply_delta(block=...)) — the Gopher Wire block
criterion. Writes BENCH_incremental.json.
"""
from __future__ import annotations

import numpy as np


def _reopened_edges(g, rows: int, cols: int, count: int, seed: int):
    """Sample `count` grid edges that were dropped at build time."""
    rng = np.random.default_rng(seed)
    v = np.arange(rows * cols).reshape(rows, cols)
    grid = np.concatenate([
        np.stack([v[:, :-1].ravel(), v[:, 1:].ravel()], 1),
        np.stack([v[:-1, :].ravel(), v[1:, :].ravel()], 1)])
    a = g.csr()
    present = np.asarray(a[grid[:, 1], grid[:, 0]]).ravel() > 0
    absent = grid[~present]
    sel = rng.choice(absent.shape[0], size=min(count, absent.shape[0]),
                     replace=False)
    return absent[sel, 0], absent[sel, 1]


def run(write_json: bool = True):
    from benchmarks.common import NUM_PARTS, emit, get_pg, timed, \
        write_bench_json
    from repro.algorithms import (bfs, connected_components,
                                  incremental_bfs,
                                  incremental_connected_components,
                                  incremental_sssp, sssp)
    from repro.core import (GopherEngine, SemiringProgram, init_max_vertex,
                            make_sssp_init)
    from repro.gofs import bfs_grow_partition, road_grid
    from repro.gofs.formats import partition_graph
    from repro.gofs.temporal import EdgeDelta, apply_delta

    g_u, pg_u = get_pg("RN")                       # unit weights: CC + BFS
    g_w = road_grid(100, 100, drop_frac=0.03, seed=1, weighted=True)
    pg_w = partition_graph(g_w, bfs_grow_partition(g_w, NUM_PARTS, seed=0),
                           NUM_PARTS)

    def post_cc(pg, x):
        return np.where(pg.vmask, x, -1).astype(np.int64)

    def post_dist(pg, x):
        return np.where(pg.vmask, x, np.inf)

    records = {"dataset": "RN", "n": g_u.n}

    def bench(algo, g, pg0, semiring, init_fn, post, inc_fn, weighted):
        from repro.core import device_block, host_graph_block
        num_ins = max(1, (g.nnz // 2) // 100)      # 1% insert batch
        iu, iv = _reopened_edges(g, 100, 100, num_ins, seed=7)
        # reopened segments carry typical-to-slow travel times (upper half of
        # the build distribution) — not magic shortcuts that would re-route
        # half the grid; their impact stays local, like real road reopenings
        iw = (np.random.default_rng(8).uniform(5.0, 10.0, iu.size)
              .astype(np.float32) if weighted else None)
        delta = EdgeDelta.inserts(iu, iv, iw)
        hb0 = host_graph_block(pg0)                # version-k block (held by
                                                   # the serving fleet)
        res = apply_delta(pg0, delta, directed=False, block=hb0)
        pg1 = res.pg

        # per-version fixed cost of the GRAPH-BLOCK BUILD (timed first, at
        # ingest position in the pipeline): cold re-pack of the derived
        # arrays vs replaying the delta's patch-event log over the
        # version-k block (what apply_delta(block=...) does inline)
        from repro.core import patch_host_block
        _, dt_block_cold = timed(lambda: host_graph_block(pg1),
                                 warmup=True, repeats=20)
        _, dt_block_patch = timed(
            lambda: patch_host_block(hb0, pg1, *res.events),
            warmup=True, repeats=20)
        block_speedup = dt_block_cold / dt_block_patch

        prog = SemiringProgram(semiring=semiring, init_fn=init_fn)
        eng = GopherEngine(pg1, prog)              # steady-state engine
        (st_full, t_full), dt_full = timed(eng.run, warmup=True, repeats=3)
        full = post(pg1, np.asarray(st_full["x"]))

        def inc():
            r = apply_delta(pg0, delta, directed=False, block=hb0)
            return inc_fn(r, device_block(r.block))

        (inc_out, t_inc), dt_inc = timed(inc, warmup=True, repeats=3)
        assert np.array_equal(full, inc_out), \
            f"{algo}: incremental != full recompute"
        speedup = dt_full / dt_inc

        emit(f"incremental_{algo}_full_RN", dt_full,
             f"supersteps={t_full.supersteps}")
        emit(f"incremental_{algo}_inc_RN", dt_inc,
             f"supersteps={t_inc.supersteps};speedup={speedup:.1f}x")
        emit(f"incremental_{algo}_block_RN", dt_block_patch,
             f"cold={dt_block_cold * 1e6:.0f}us;speedup={block_speedup:.1f}x")
        records[algo] = dict(
            full_us=round(dt_full * 1e6), inc_us=round(dt_inc * 1e6),
            speedup=round(speedup, 2), bit_identical=True,
            insert_edges=int(iu.size),
            full_supersteps=int(t_full.supersteps),
            inc_supersteps=int(t_inc.supersteps),
            full_local_iters=int(t_full.local_iters.sum()),
            inc_local_iters=int(t_inc.local_iters.sum()),
            inc_wire_slots=int(t_inc.wire_slots),
            full_wire_slots=int(t_full.wire_slots),
            block_cold_us=round(dt_block_cold * 1e6),
            block_patch_us=round(dt_block_patch * 1e6),
            block_fixed_speedup=round(block_speedup, 2))

    prev_cc = connected_components(pg_u)[0]
    prev_bfs = bfs(pg_u, 0)[0]
    prev_sssp = sssp(pg_w, 0)[0]

    bench("cc", g_u, pg_u, "max_first", init_max_vertex, post_cc,
          lambda r, gb: incremental_connected_components(
              r.pg, prev_cc, r, gb=gb)[::2],
          weighted=False)
    bench("bfs", g_u, pg_u, "min_plus",
          make_sssp_init(int(pg_u.part_of[0]), int(pg_u.local_of[0])),
          post_dist,
          lambda r, gb: incremental_bfs(r.pg, 0, prev_bfs, r, gb=gb),
          weighted=False)
    bench("sssp", g_w, pg_w, "min_plus",
          make_sssp_init(int(pg_w.part_of[0]), int(pg_w.local_of[0])),
          post_dist,
          lambda r, gb: incremental_sssp(r.pg, 0, prev_sssp, r, gb=gb),
          weighted=True)

    if write_json:
        write_bench_json("incremental", records)
    return records


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
