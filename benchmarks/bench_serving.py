"""Gopher Serve throughput: batched multi-query BSP vs sequential queries.

Three tiers serve the SAME SSSP query stream over the synthetic powerlaw
graph:

  naive       the pre-serving per-query path (``algorithms.sssp``): one
              engine + one program PER QUERY. The source is baked into the
              program's init closure, so every query re-traces and
              re-compiles its own BSP loop — this is what "sequential
              single-query runs" cost before the serving subsystem existed.
  sequential  one query per engine run through a bucket-size-1
              GraphQueryService: the STRONG baseline — it already shares the
              serving subsystem's graph block, gather-form mailbox, and jit
              cache across queries, and differs from batched only in the
              query axis.
  batched     ceil(N/Q) engine runs with the query axis at Q.

sequential/batched are warmed (compilation excluded) and interleaved, with
the speedup taken as the MEDIAN of per-repeat paired ratios so background
load drift cancels. naive cannot be warmed — per-query re-compilation IS its
cost — so it is measured on a few queries and scaled.

Emits CSV rows ``serving_{naive|seq|batched}_Q{n}, us_per_stream, ...``.
The acceptance bar (>=3x QPS at Q=16 over sequential single-query runs) is
evaluated against the naive tier; the strong-baseline ratio is reported
alongside for honesty — it isolates the pure query-axis win (shared
supersteps + amortized per-run overhead) from the compile/cache win.
"""
from __future__ import annotations

import time

import numpy as np

from repro.algorithms import sssp as sssp_single
from repro.gofs import bfs_grow_partition, powerlaw_social
from repro.gofs.formats import partition_graph
from repro.serving import GraphQueryService

BATCH_SIZES = (1, 4, 16, 64)
N_TOTAL = 64               # queries per timed stream
N_VERTICES = 1000          # interactive-scale graph: per-query latency ~ms
NUM_PARTS = 4
REPEATS = 7
NAIVE_SAMPLES = 4          # naive tier is compile-bound; sample + scale


def _service(pg, max_batch):
    return GraphQueryService({"social": pg}, max_batch=max_batch,
                             cache_capacity=0)  # no memo: measure the engine


def _serve(svc, sources, wave):
    for i in range(0, len(sources), wave):
        for s in sources[i:i + wave]:
            svc.submit("sssp", "social", int(s))
        svc.drain()


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.0f},{derived}")


def run():
    g = powerlaw_social(N_VERTICES, m=4, seed=3)
    pg = partition_graph(g, bfs_grow_partition(g, NUM_PARTS, seed=0), NUM_PARTS)
    rng = np.random.default_rng(0)

    # naive tier: per-query engine construction + re-trace (the pre-serving
    # status quo) — sampled, then scaled to the stream length
    naive_srcs = rng.integers(0, pg.n_global, size=NAIVE_SAMPLES)
    sssp_single(pg, int(naive_srcs[0]))
    t0 = time.perf_counter()
    for s in naive_srcs:
        sssp_single(pg, int(s))
    dt_naive_q = (time.perf_counter() - t0) / NAIVE_SAMPLES
    dt_naive = dt_naive_q * N_TOTAL
    emit("serving_naive", dt_naive,
         f"qps={1.0 / dt_naive_q:.1f};per_query_ms={dt_naive_q * 1e3:.0f}")

    results = {}
    for q in BATCH_SIZES:
        sources = rng.integers(0, pg.n_global, size=N_TOTAL)
        seq = _service(pg, max_batch=1)
        bat = _service(pg, max_batch=q)
        _serve(seq, sources, 1)          # warm both jit caches
        _serve(bat, sources, q)
        dt_seq = dt_bat = np.inf
        ratios = []
        for _ in range(REPEATS):         # interleaved; drift cancels per pair
            t0 = time.perf_counter()
            _serve(seq, sources, 1)
            t_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            _serve(bat, sources, q)
            t_b = time.perf_counter() - t0
            dt_seq, dt_bat = min(dt_seq, t_s), min(dt_bat, t_b)
            ratios.append(t_s / t_b)
        vs_seq = float(np.median(ratios))
        vs_naive = dt_naive / dt_bat
        results[q] = dict(vs_naive=vs_naive, vs_seq=vs_seq)
        emit(f"serving_seq_Q{q}", dt_seq, f"qps={N_TOTAL / dt_seq:.1f}")
        emit(f"serving_batched_Q{q}", dt_bat,
             f"qps={N_TOTAL / dt_bat:.1f};vs_single_query={vs_naive:.0f}x;"
             f"vs_seq_service={vs_seq:.2f}x")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    res = run()
    r16 = res.get(16, {})
    ok = r16.get("vs_naive", 0.0) >= 3.0
    print(f"acceptance: batched Q=16 is {r16.get('vs_naive', 0.0):.0f}x the "
          "sequential single-query path (>= 3x required) -> "
          f"{'PASS' if ok else 'FAIL'}; "
          f"{r16.get('vs_seq', 0.0):.2f}x the compile-cached sequential "
          "service (the strong baseline)")
